//! Block-cache integration tests: cached streaming runs must be
//! bit-identical to uncached ones on every native backend, cache
//! hit/miss counts must be exactly predictable under a deterministic
//! schedule, positioned reads must be safe under heavy thread
//! contention, and diagonal tasks must never fetch a second block.

use bulkmi::coordinator::blockcache::{BlockCache, CacheHandle};
use bulkmi::coordinator::executor::{
    run_plan, run_plan_dense, NativeKind, NativeProvider,
};
use bulkmi::coordinator::planner::plan_blocks;
use bulkmi::coordinator::progress::Progress;
use bulkmi::coordinator::scheduler::{order_tasks, Schedule};
use bulkmi::data::colstore::{ColumnSource, InMemorySource, PackedFileSource};
use bulkmi::data::io::write_bmat_v2;
use bulkmi::data::synth::SynthSpec;
use bulkmi::linalg::bitmat::BitMatrix;
use bulkmi::mi::measure::CombineKind;
use bulkmi::mi::sink::{MiSink, SinkData, TopKSink};
use bulkmi::util::error::Result;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("bulkmi-blockcache-{}-{name}.bmat", std::process::id()))
}

/// Property: for every native substrate kind, a cached streaming run
/// (panel order, prefetch on, multiple workers) produces bit-identical
/// results to the uncached run over the same file — through both the
/// dense path and a top-k sink.
#[test]
fn cached_runs_are_bit_identical_to_uncached() {
    let ds = SynthSpec::new(300, 48).sparsity(0.8).seed(11).generate();
    let path = tmp("ident");
    write_bmat_v2(&ds, &path).unwrap();
    let src = PackedFileSource::open(&path).unwrap();
    for kind in [NativeKind::Bitpack, NativeKind::Dense, NativeKind::Sparse] {
        let plan = plan_blocks(48, 6).unwrap();
        let progress = Progress::new(plan.tasks.len());
        let uncached = run_plan_dense(
            &src,
            &plan,
            &NativeProvider::new(&src, kind),
            2,
            &progress,
            CombineKind::Mi,
        )
        .unwrap();

        let mut plan = plan_blocks(48, 6).unwrap();
        order_tasks(&mut plan.tasks, Schedule::Panel);
        let handle = CacheHandle::fresh(Arc::new(BlockCache::new(32 << 20)));
        let provider = NativeProvider::with_cache(&src, kind, handle, 2);
        let progress = Progress::new(plan.tasks.len());
        let cached =
            run_plan_dense(&src, &plan, &provider, 3, &progress, CombineKind::Mi).unwrap();
        assert_eq!(cached.max_abs_diff(&uncached), 0.0, "{kind:?}");
    }

    // the matrix-free sink path agrees pair for pair
    let mut topk_runs = Vec::new();
    for cached in [false, true] {
        let mut plan = plan_blocks(48, 6).unwrap();
        let handle = CacheHandle::fresh(Arc::new(BlockCache::new(32 << 20)));
        let provider = if cached {
            order_tasks(&mut plan.tasks, Schedule::Panel);
            NativeProvider::with_cache(&src, NativeKind::Bitpack, handle, 1)
        } else {
            NativeProvider::new(&src, NativeKind::Bitpack)
        };
        let mut sink = TopKSink::global(12);
        let progress = Progress::new(plan.tasks.len());
        run_plan(&src, &plan, &provider, 2, &progress, &mut sink, CombineKind::Mi).unwrap();
        match sink.finish().unwrap().data {
            SinkData::TopK(pairs) => topk_runs.push(pairs),
            other => panic!("unexpected sink output {}", other.kind_name()),
        }
    }
    assert_eq!(topk_runs[0], topk_runs[1], "top-k pairs differ cached vs uncached");
    let _ = std::fs::remove_file(&path);
}

/// With one worker and no readahead the executor requests substrates in
/// exact panel order, so the cache's hit/miss/eviction counters are
/// fully predictable — both unbounded and with a budget of exactly two
/// entries.
#[test]
fn panel_schedule_hit_counts_are_deterministic() {
    // m = 16, block = 4: panel order is
    // (0,0) (0,4) (0,8) (0,12) (4,12) (4,8) (4,4) (8,8) (8,12) (12,12)
    // with per-task requests a then b — 16 requests over 4 blocks.
    let ds = SynthSpec::new(128, 16).sparsity(0.7).seed(21).generate();
    let one_substrate_bytes = {
        // 128 rows = 2 words per column, 4 columns per block
        2 * 4 * 8
    };

    // unbounded: every block builds once, every revisit hits
    let cache = Arc::new(BlockCache::new(1 << 20));
    run_panel(&ds, &cache);
    let s = cache.stats();
    assert_eq!((s.misses, s.hits, s.evictions), (4, 12, 0), "unbounded: {s:?}");

    // capacity of exactly two substrates: hand-simulated LRU gives
    // 7 misses / 9 hits / 5 evictions for the serpentine order above
    let cache = Arc::new(BlockCache::new(2 * one_substrate_bytes));
    run_panel(&ds, &cache);
    let s = cache.stats();
    assert_eq!((s.misses, s.hits, s.evictions), (7, 9, 5), "capacity 2: {s:?}");
}

fn run_panel(ds: &bulkmi::data::dataset::BinaryDataset, cache: &Arc<BlockCache>) {
    let mut plan = plan_blocks(16, 4).unwrap();
    order_tasks(&mut plan.tasks, Schedule::Panel);
    // workers = 1 runs tasks inline in plan order; readahead = 0 keeps
    // the prefetch thread (and its racy request interleaving) out
    let provider =
        NativeProvider::with_cache(ds, NativeKind::Bitpack, CacheHandle::fresh(Arc::clone(cache)), 0);
    let progress = Progress::new(plan.tasks.len());
    run_plan_dense(ds, &plan, &provider, 1, &progress, CombineKind::Mi).unwrap();
}

/// Positioned reads share one file handle with no seek state: many
/// threads hammering random `col_block` ranges must each get exactly
/// the bytes an in-memory packing of the same dataset holds.
#[test]
fn concurrent_col_block_reads_are_bit_identical() {
    let n_rows = 997; // odd shape: 16 words per column, last word partial
    let n_cols = 37;
    let ds = SynthSpec::new(n_rows, n_cols).sparsity(0.6).seed(31).generate();
    let path = tmp("concurrent");
    write_bmat_v2(&ds, &path).unwrap();
    let src = Arc::new(PackedFileSource::open(&path).unwrap());
    let reference = ds.to_bitmatrix();
    let before = src.io_stats().unwrap();

    let expected_bytes: u64 = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let src = Arc::clone(&src);
            let reference = &reference;
            handles.push(scope.spawn(move || {
                let mut state = 0x9e37_79b9_7f4a_7c15u64.wrapping_mul(t + 1);
                let mut bytes = 0u64;
                for _ in 0..50 {
                    // LCG per thread: deterministic but thread-unique ranges
                    state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                    let start = (state >> 33) as usize % n_cols;
                    state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                    let len = 1 + (state >> 33) as usize % (n_cols - start);
                    let got = src.col_block(start, len).unwrap();
                    let want = reference.col_block(start, len).unwrap();
                    assert_eq!(got.words(), want.words(), "block [{start}, {start}+{len})");
                    bytes += (len * got.words_per_col() * 8) as u64;
                }
                bytes
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });

    let delta = src.io_stats().unwrap().since(&before);
    assert_eq!(delta.bytes_read, expected_bytes, "byte accounting");
    assert_eq!(delta.reads, 8 * 50, "one positioned read per col_block");
    let _ = std::fs::remove_file(&path);
}

/// A source wrapper counting `col_block` calls. Diagonal tasks must
/// fetch exactly one block, so an uncached plan over `nb` blocks and
/// `T` tasks costs `nb` (colsums) + `nb` (diagonals) + `2 (T - nb)`
/// (off-diagonals) fetches — no hidden re-fetch on any path.
struct CountingSource {
    inner: InMemorySource,
    calls: AtomicUsize,
}

impl ColumnSource for CountingSource {
    fn n_rows(&self) -> usize {
        self.inner.n_rows()
    }

    fn n_cols(&self) -> usize {
        self.inner.n_cols()
    }

    fn names(&self) -> Option<&[String]> {
        self.inner.names()
    }

    fn col_block(&self, start: usize, len: usize) -> Result<BitMatrix> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.inner.col_block(start, len)
    }
}

#[test]
fn diagonal_tasks_fetch_exactly_one_block() {
    let ds = SynthSpec::new(200, 16).sparsity(0.5).seed(41).generate();
    let src = CountingSource { inner: InMemorySource::new(&ds), calls: AtomicUsize::new(0) };
    let plan = plan_blocks(16, 4).unwrap(); // nb = 4, T = 10
    let provider = NativeProvider::new(&src, NativeKind::Bitpack);
    let progress = Progress::new(plan.tasks.len());
    run_plan_dense(&src, &plan, &provider, 1, &progress, CombineKind::Mi).unwrap();
    let nb = 4;
    let t = plan.tasks.len();
    assert_eq!(src.calls.load(Ordering::Relaxed), nb + nb + 2 * (t - nb));
}
