//! Kernel-equivalence property tests: every AND-popcount kernel the
//! dispatch table can commit to (scalar, portable Harley–Seal CSA, and
//! the runtime-detected ISA kernels — AVX2 and AVX-512 `VPOPCNTQ` on
//! x86-64, NEON on aarch64) must produce **bit-identical** `gram` /
//! `gram_cross` results on arbitrary ragged shapes — including row
//! counts that are not multiples of 64 (partial tail word), word counts
//! hitting every unroll remainder, and degenerate 1-column matrices.
//! The property loops run over `kernels::available()`, so a kernel is
//! covered automatically on every host whose CPU can dispatch it.
//! Selection is a throughput decision only; these tests are what makes
//! that claim safe.

use bulkmi::data::dataset::BinaryDataset;
use bulkmi::linalg::bitmat::BitMatrix;
use bulkmi::linalg::kernels;
use bulkmi::util::prop::{gen, prop_check, Config};

fn bitmatrix(n: usize, m: usize, bytes: &[u8]) -> BitMatrix {
    BitMatrix::from_row_major(n, m, bytes).unwrap()
}

#[test]
fn prop_every_kernel_gram_bit_identical_to_reference() {
    prop_check(
        "gram_with(kernel) == gram_reference",
        Config::with_cases(32),
        // up to 300 rows: exercises 1..5 words per column, most with a
        // ragged tail word; up to 13 cols: every 4-wide unroll remainder
        |rng| gen::binary_matrix(rng, 300, 13),
        |(n, m, bytes)| {
            let bm = bitmatrix(*n, *m, bytes);
            let want = bm.gram_reference();
            for kernel in kernels::available() {
                let got = bm.gram_with(kernel);
                let diff = got.max_abs_diff(&want);
                if diff != 0.0 {
                    return Err(format!("{} n={n} m={m}: diff {diff}", kernel.name()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_every_kernel_gram_cross_bit_identical() {
    prop_check(
        "gram_cross_with(kernel) == reference cross",
        Config::with_cases(32),
        |rng| {
            let (n, ma, bytes_a) = gen::binary_matrix(rng, 260, 9);
            let mb = gen::int_in(rng, 1, 9);
            let bytes_b: Vec<u8> = (0..n * mb)
                .map(|_| if rng.bernoulli(0.4) { 1 } else { 0 })
                .collect();
            (n, ma, bytes_a, mb, bytes_b)
        },
        |(n, ma, bytes_a, mb, bytes_b)| {
            let a = bitmatrix(*n, *ma, bytes_a);
            let b = bitmatrix(*n, *mb, bytes_b);
            let want = a.gram_cross_with(&b, kernels::reference()).unwrap();
            for kernel in kernels::available() {
                let got = a.gram_cross_with(&b, kernel).unwrap();
                let diff = got.max_abs_diff(&want);
                if diff != 0.0 {
                    return Err(format!(
                        "{} n={n} {ma}x{mb}: diff {diff}",
                        kernel.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The tail-word path specifically: row counts straddling word
/// boundaries (63/64/65...) with all-ones data, where a kernel that
/// read past the packed tail would overcount deterministically.
#[test]
fn tail_word_boundaries_exact() {
    for n in [1usize, 63, 64, 65, 127, 128, 129, 191, 256, 257] {
        let m = 5;
        let bytes = vec![1u8; n * m];
        let bm = bitmatrix(n, m, &bytes);
        for kernel in kernels::available() {
            let g = bm.gram_with(kernel);
            for i in 0..m {
                for j in 0..m {
                    assert_eq!(
                        g.get(i, j),
                        n as f64,
                        "{} n={n} ({i},{j})",
                        kernel.name()
                    );
                }
            }
        }
    }
}

/// ISA kernels appear in the eligible set exactly when this CPU has the
/// feature, and never on a foreign architecture — the "cleanly absent"
/// half of the acceptance criteria.
#[test]
fn isa_kernels_present_only_when_detected() {
    let names: Vec<&str> = kernels::available().iter().map(|k| k.name()).collect();
    #[cfg(target_arch = "x86_64")]
    {
        assert_eq!(
            names.contains(&"avx2"),
            std::arch::is_x86_feature_detected!("avx2")
        );
        assert_eq!(
            names.contains(&"avx512"),
            std::arch::is_x86_feature_detected!("avx512f")
                && std::arch::is_x86_feature_detected!("avx512vpopcntdq")
        );
        assert!(!names.contains(&"neon"));
    }
    #[cfg(target_arch = "aarch64")]
    {
        assert!(names.contains(&"neon"), "NEON is baseline on aarch64");
        assert!(!names.contains(&"avx2"));
        assert!(!names.contains(&"avx512"));
    }
    for name in &names {
        assert!(kernels::known_names().contains(name), "{name} unknown");
    }
}

/// The committed (dispatched) kernel is one of the available ones and
/// the full MI pipeline through it matches the textbook baseline.
#[test]
fn dispatched_kernel_end_to_end_matches_pairwise() {
    use bulkmi::mi::backend::{compute_mi, Backend};

    let table = kernels::KernelDispatch::global();
    assert!(kernels::available()
        .iter()
        .any(|k| k.name() == table.active().name()));

    let (n, m) = (257, 12);
    let bytes: Vec<u8> = (0..n * m).map(|i| ((i * 2654435761) >> 7) as u8 & 1).collect();
    let ds = BinaryDataset::new(n, m, bytes).unwrap();
    let want = compute_mi(&ds, Backend::Pairwise).unwrap();
    let got = compute_mi(&ds, Backend::BulkBitpack).unwrap();
    assert!(
        got.max_abs_diff(&want) < 1e-10,
        "kernel {}: diff {}",
        table.active().name(),
        got.max_abs_diff(&want)
    );
}
