//! Column-source integration tests: format round trips (CSV → `pack` →
//! `.bmat` v2 → block reads, bit for bit against the in-memory source),
//! v1 backward compatibility, and the out-of-core acceptance run — a
//! dataset whose `Vec<u8>` form exceeds the planner budget, streamed
//! through a `PackedFileSource` and bit-identical to the in-memory run
//! on every native backend including `auto`.

use bulkmi::coordinator::executor::NativeKind;
use bulkmi::coordinator::planner::{block_for_budget, plan_blocks, task_bytes};
use bulkmi::coordinator::progress::Progress;
use bulkmi::coordinator::{run_plan, run_plan_dense, NativeProvider};
use bulkmi::data::colstore::{ColumnSource, InMemorySource, PackedFileSource};
use bulkmi::data::dataset::BinaryDataset;
use bulkmi::data::io;
use bulkmi::data::synth::SynthSpec;
use bulkmi::mi::backend::{compute_mi, Backend};
use bulkmi::mi::measure::CombineKind;
use bulkmi::mi::sink::{SinkData, TopKSink};
use bulkmi::mi::topk::top_k_pairs;
use bulkmi::util::prop::{gen, prop_check, Config};
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bulkmi-colstore-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Assert two sources serve identical metadata and identical bits for a
/// spread of block shapes (full width, unit columns, tails).
fn assert_sources_equal(a: &dyn ColumnSource, b: &dyn ColumnSource, ctx: &str) {
    assert_eq!(a.n_rows(), b.n_rows(), "{ctx}: n_rows");
    assert_eq!(a.n_cols(), b.n_cols(), "{ctx}: n_cols");
    assert_eq!(a.names(), b.names(), "{ctx}: names");
    let m = a.n_cols();
    let mut shapes = vec![(0usize, m)];
    if m > 0 {
        shapes.push((m - 1, 1)); // last column alone (tail)
        shapes.push((0, 1));
        shapes.push((m / 2, m - m / 2)); // tail-heavy block
        if m >= 3 {
            shapes.push((1, m - 2)); // interior block
        }
    }
    for (start, len) in shapes {
        let ba = a.col_block(start, len).unwrap();
        let bb = b.col_block(start, len).unwrap();
        assert_eq!(ba.words(), bb.words(), "{ctx}: block [{start}, {start}+{len})");
        assert_eq!(
            a.col_counts_block(start, len).unwrap(),
            b.col_counts_block(start, len).unwrap(),
            "{ctx}: counts [{start}, {start}+{len})"
        );
    }
    assert_eq!(
        a.all_col_counts(3).unwrap(),
        b.all_col_counts(0).unwrap(),
        "{ctx}: all counts (different chunkings)"
    );
    // out-of-range blocks rejected by both
    assert!(a.col_block(m, 1).is_err(), "{ctx}");
    assert!(b.col_block(m, 1).is_err(), "{ctx}");
}

/// CSV → `pack` → v2 → `ColumnSource::col_block` equals the in-memory
/// source bit for bit, across random shapes (rows straddling word
/// boundaries, tail columns) with and without column names.
#[test]
fn prop_csv_pack_v2_round_trips_bit_for_bit() {
    prop_check(
        "csv -> pack -> v2 == in-memory",
        Config::with_cases(12),
        |rng| {
            let (n, m, bytes) = gen::binary_matrix(rng, 200, 20);
            let named = gen::int_in(rng, 0, 1) == 1;
            let chunk = gen::int_in(rng, 1, 130); // pack rounds up to 64
            (n, m, bytes, named, chunk)
        },
        |(n, m, bytes, named, chunk)| {
            let mut ds = BinaryDataset::new(*n, *m, bytes.clone()).map_err(|e| e.to_string())?;
            if *named {
                ds = ds
                    .with_names((0..*m).map(|c| format!("var_{c}")).collect())
                    .map_err(|e| e.to_string())?;
            }
            let csv = tmp(&format!("prop-{n}-{m}-{named}.csv"));
            let v2 = tmp(&format!("prop-{n}-{m}-{named}.bmat"));
            io::write_csv(&ds, &csv, *named).map_err(|e| e.to_string())?;
            io::pack(&csv, &v2, *chunk).map_err(|e| e.to_string())?;
            let packed = PackedFileSource::open(&v2).map_err(|e| e.to_string())?;
            let mem = InMemorySource::new(&ds);
            assert_sources_equal(&packed, &mem, &format!("n={n} m={m} named={named}"));
            Ok(())
        },
    );
}

#[test]
fn zero_row_and_zero_col_edges() {
    // 0 rows, named columns
    let ds = BinaryDataset::new(0, 4, vec![])
        .unwrap()
        .with_names((0..4).map(|c| format!("c{c}")).collect())
        .unwrap();
    let path = tmp("edge-0rows.bmat");
    io::write_bmat_v2(&ds, &path).unwrap();
    let packed = PackedFileSource::open(&path).unwrap();
    assert_sources_equal(&packed, &InMemorySource::new(&ds), "0 rows");
    assert_eq!(packed.col_block(0, 4).unwrap().rows(), 0);

    // 0 columns
    let none = BinaryDataset::new(7, 0, vec![]).unwrap();
    let path = tmp("edge-0cols.bmat");
    io::write_bmat_v2(&none, &path).unwrap();
    let packed = PackedFileSource::open(&path).unwrap();
    assert_sources_equal(&packed, &InMemorySource::new(&none), "0 cols");
}

/// v1 files still read back exactly (backward compatibility), and a v1
/// → v2 `pack` serves the same bits.
#[test]
fn v1_backward_compat_reads_and_packs() {
    let ds = SynthSpec::new(331, 19).sparsity(0.75).seed(77).generate();
    let v1 = tmp("compat.bmat");
    io::write_bmat(&ds, &v1).unwrap();
    assert!(!io::is_bmat_v2(&v1).unwrap());
    let back = io::load(&v1).unwrap();
    assert_eq!(back.bytes(), ds.bytes(), "v1 load is unchanged");
    let v2 = tmp("compat-v2.bmat");
    io::pack(&v1, &v2, 64).unwrap();
    let packed = PackedFileSource::open(&v2).unwrap();
    assert_sources_equal(&packed, &InMemorySource::new(&ds), "v1 -> v2");
}

/// The acceptance criterion: a dataset whose one-byte-per-cell form
/// exceeds the planner budget runs through `PackedFileSource` under
/// that budget (block sizing keeps `task_bytes(n, b)` within it) and
/// every native backend — and `auto` — produces results bit-identical
/// to the in-memory run.
#[test]
fn out_of_core_run_bit_identical_on_every_backend() {
    const BUDGET: usize = 256 << 10; // 256 KiB
    let (n, m) = (20_000usize, 64usize);
    let ds = SynthSpec::new(n, m).sparsity(0.9).seed(91).plant(3, 40, 0.02).generate();
    assert!(
        n * m > BUDGET,
        "the dataset's Vec<u8> form ({} bytes) must exceed the budget ({BUDGET})",
        n * m
    );
    let block = block_for_budget(n, m, BUDGET);
    assert!(
        task_bytes(n, block) <= BUDGET || block == 1,
        "block sizing must respect the budget"
    );

    let path = tmp("acceptance.bmat");
    io::write_bmat_v2(&ds, &path).unwrap();
    let want = compute_mi(&ds, Backend::BulkBitpack).unwrap();

    let packed = PackedFileSource::open(&path).unwrap();
    let mem = InMemorySource::new(&ds);
    let plan = plan_blocks(m, block).unwrap();
    for kind in [NativeKind::Bitpack, NativeKind::Dense, NativeKind::Sparse] {
        let from_disk = run_plan_dense(
            &packed,
            &plan,
            &NativeProvider::new(&packed, kind),
            2,
            &Progress::new(plan.tasks.len()),
            CombineKind::Mi,
        )
        .unwrap();
        let from_mem = run_plan_dense(
            &mem,
            &plan,
            &NativeProvider::new(&mem, kind),
            2,
            &Progress::new(plan.tasks.len()),
            CombineKind::Mi,
        )
        .unwrap();
        assert_eq!(
            from_disk.max_abs_diff(&from_mem),
            0.0,
            "{kind:?}: packed-file run must be bit-identical to the in-memory run"
        );
        assert_eq!(
            from_disk.max_abs_diff(&want),
            0.0,
            "{kind:?}: blockwise streaming run must equal the monolithic result"
        );
    }

    // `--backend auto`: resolve through the packed source, then run the
    // chosen substrate out of core — still bit-identical.
    let (chosen, probe) = Backend::Auto.resolve_source(&packed).unwrap();
    assert!(chosen.is_native());
    assert!(probe.is_some(), "auto must carry its probe report");
    let auto_run = run_plan_dense(
        &packed,
        &plan,
        &NativeProvider::new(&packed, chosen.native_kind()),
        2,
        &Progress::new(plan.tasks.len()),
        CombineKind::Mi,
    )
    .unwrap();
    assert_eq!(auto_run.max_abs_diff(&want), 0.0, "auto ({chosen}) out-of-core run");

    // a matrix-free sink over the same streamed plan matches post-hoc
    // extraction from the full matrix
    let mut sink = TopKSink::global(5);
    run_plan(
        &packed,
        &plan,
        &NativeProvider::new(&packed, NativeKind::Bitpack),
        2,
        &Progress::new(plan.tasks.len()),
        &mut sink,
        CombineKind::Mi,
    )
    .unwrap();
    let SinkData::TopK(got) = sink.finish().unwrap().data else { panic!() };
    let exp = top_k_pairs(&want, 5);
    assert_eq!(got.len(), exp.len());
    for (g, w) in got.iter().zip(&exp) {
        assert_eq!((g.i, g.j), (w.i, w.j));
        assert_eq!(g.mi, w.mi);
    }
    assert_eq!((got[0].i, got[0].j), (3, 40), "planted pair surfaces first");
}
