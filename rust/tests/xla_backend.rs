//! Integration tests for the PJRT runtime + XLA MI backend against the
//! real AOT artifacts. Requires `make artifacts` to have run (skips,
//! loudly, when the artifact directory is absent — e.g. in a tree where
//! only cargo ran).

// The numeric checks deliberately index by (row, col) to mirror the
// paper's pseudocode (same rationale as the crate-level allow in lib.rs).
#![allow(clippy::needless_range_loop)]

use bulkmi::data::synth::SynthSpec;
use bulkmi::mi::backend::{compute_mi, Backend};
use bulkmi::mi::xla::XlaMi;
use bulkmi::runtime::{ArtifactKind, ArtifactRegistry, Impl, XlaRuntime};
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var("BULKMI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!(
            "SKIPPING xla integration tests: no artifacts at {} (run `make artifacts`)",
            dir.display()
        );
        None
    }
}

fn registry() -> Option<ArtifactRegistry> {
    artifacts_dir().map(|d| ArtifactRegistry::load(&d).expect("manifest parses"))
}

#[test]
fn manifest_has_all_kinds() {
    let Some(reg) = registry() else { return };
    for kind in [
        ArtifactKind::Mi,
        ArtifactKind::Gram,
        ArtifactKind::Xgram,
        ArtifactKind::Combine,
        ArtifactKind::MiBasic,
    ] {
        assert!(
            reg.all().iter().any(|a| a.kind == kind),
            "no artifact of kind {kind:?} in manifest"
        );
    }
    // both impls present
    assert!(reg.all().iter().any(|a| a.impl_ == Impl::Pallas));
    assert!(reg.all().iter().any(|a| a.impl_ == Impl::Xla));
}

#[test]
fn fused_mi_matches_pairwise_small() {
    let Some(reg) = registry() else { return };
    let rt = XlaRuntime::new(reg).unwrap();
    let ds = SynthSpec::new(300, 40).sparsity(0.9).seed(1).generate();
    let d: Vec<f32> = ds.bytes().iter().map(|&b| b as f32).collect();
    let flat = rt.run_mi_fused(Impl::Xla, &d, 300, 40).unwrap();
    let want = compute_mi(&ds, Backend::Pairwise).unwrap();
    for i in 0..40 {
        for j in 0..40 {
            let diff = (flat[i * 40 + j] - want.get(i, j)).abs();
            assert!(diff < 1e-4, "({i},{j}): {} vs {}", flat[i * 40 + j], want.get(i, j));
        }
    }
}

#[test]
fn pallas_impl_matches_xla_impl() {
    let Some(reg) = registry() else { return };
    let rt = XlaRuntime::new(reg).unwrap();
    let ds = SynthSpec::new(500, 60).sparsity(0.8).seed(2).generate();
    let d: Vec<f32> = ds.bytes().iter().map(|&b| b as f32).collect();
    let a = rt.run_mi_fused(Impl::Xla, &d, 500, 60).unwrap();
    let b = rt.run_mi_fused(Impl::Pallas, &d, 500, 60).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-5, "{x} vs {y}");
    }
}

#[test]
fn gram_partials_accumulate_exactly() {
    let Some(reg) = registry() else { return };
    let rt = XlaRuntime::new(reg).unwrap();
    let ds = SynthSpec::new(5000, 50).sparsity(0.9).seed(3).generate();
    let d: Vec<f32> = ds.bytes().iter().map(|&b| b as f32).collect();
    // chunked accumulation
    let mut g = vec![0.0f64; 50 * 50];
    let mut c = vec![0.0f64; 50];
    for chunk in [(0usize, 2048usize), (2048, 2048), (4096, 904)] {
        let (lo, len) = chunk;
        let (gp, cp) = rt.run_gram(Impl::Xla, &d[lo * 50..(lo + len) * 50], len, 50).unwrap();
        for (a, v) in g.iter_mut().zip(&gp) {
            *a += v;
        }
        for (a, v) in c.iter_mut().zip(&cp) {
            *a += v;
        }
    }
    // exact integer counts expected
    let bit = ds.to_bitmatrix();
    for i in 0..50 {
        for j in 0..50 {
            assert_eq!(g[i * 50 + j], bit.and_count(i, j) as f64, "G11[{i}][{j}]");
        }
    }
    let counts = ds.col_counts();
    for j in 0..50 {
        assert_eq!(c[j], counts[j] as f64);
    }
    // combine through the artifact
    let mi = rt.run_combine(Impl::Xla, &g, &c, &c, 5000.0, 50).unwrap();
    let want = compute_mi(&ds, Backend::Pairwise).unwrap();
    for i in 0..50 {
        for j in 0..50 {
            assert!((mi[i * 50 + j] - want.get(i, j)).abs() < 1e-4);
        }
    }
}

#[test]
fn xgram_cross_block_matches() {
    let Some(reg) = registry() else { return };
    let rt = XlaRuntime::new(reg).unwrap();
    let ds = SynthSpec::new(400, 30).sparsity(0.7).seed(4).generate();
    let a = ds.col_block(0, 12).unwrap();
    let b = ds.col_block(12, 18).unwrap();
    let da: Vec<f32> = a.bytes().iter().map(|&v| v as f32).collect();
    let db: Vec<f32> = b.bytes().iter().map(|&v| v as f32).collect();
    let (g, ca, cb) = rt.run_xgram(Impl::Xla, &da, &db, 400, 12, 18).unwrap();
    let bma = a.to_bitmatrix();
    let bmb = b.to_bitmatrix();
    let want = bma.gram_cross(&bmb).unwrap();
    for i in 0..12 {
        for j in 0..18 {
            assert_eq!(g[i * 18 + j], want.get(i, j));
        }
    }
    assert_eq!(ca.len(), 12);
    assert_eq!(cb.len(), 18);
}

#[test]
fn xla_backend_end_to_end_fused_path() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::load(&dir).unwrap();
    let backend = XlaMi::new(XlaRuntime::new(reg).unwrap(), Impl::Xla);
    let ds = SynthSpec::new(900, 90).sparsity(0.9).seed(5).generate();
    let got = backend.compute(&ds).unwrap();
    let want = compute_mi(&ds, Backend::BulkBitpack).unwrap();
    assert!(got.max_abs_diff(&want) < 1e-4, "diff {}", got.max_abs_diff(&want));
    assert!(got.max_asymmetry() < 1e-5);
}

#[test]
fn xla_backend_row_chunked_path() {
    // rows beyond every fused bucket force the gram+combine path
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::load(&dir).unwrap();
    let backend = XlaMi::new(XlaRuntime::new(reg).unwrap(), Impl::Xla);
    let ds = SynthSpec::new(20_000, 64).sparsity(0.95).seed(6).generate();
    let got = backend.compute(&ds).unwrap();
    let want = compute_mi(&ds, Backend::BulkBitpack).unwrap();
    assert!(got.max_abs_diff(&want) < 1e-4, "diff {}", got.max_abs_diff(&want));
}

#[test]
fn mi_basic_artifact_matches_on_exact_bucket() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = ArtifactRegistry::load(&dir).unwrap();
    let rt = XlaRuntime::new(reg).unwrap();
    let ds = SynthSpec::new(1024, 100).sparsity(0.9).seed(7).generate();
    let d: Vec<f32> = ds.bytes().iter().map(|&b| b as f32).collect();
    let got = rt.run_mi_basic(&d, 1024, 100).unwrap();
    let want = compute_mi(&ds, Backend::Pairwise).unwrap();
    for i in 0..100 {
        for j in 0..100 {
            assert!((got[i * 100 + j] - want.get(i, j)).abs() < 1e-4);
        }
    }
    // non-exact rows are rejected (padding is not exact for Section 2)
    assert!(rt.run_mi_basic(&d[..1000 * 100], 1000, 100).is_err());
}
