//! End-to-end integration: CLI surface, IO round trips through real
//! files, config-driven runs, and the full generate → store → load →
//! compute → analyze chain on each application-domain generator.

use bulkmi::cli;
use bulkmi::config::{RawConfig, RunConfig};
use bulkmi::data::genomics::GenomicsSpec;
use bulkmi::data::graph::SbmSpec;
use bulkmi::data::io;
use bulkmi::data::text::{binarize, builtin_corpus};
use bulkmi::mi::backend::{compute_mi, Backend};
use bulkmi::mi::topk::top_k_pairs;
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("bulkmi-e2e-{}-{name}", std::process::id()))
}

fn sv(xs: &[&str]) -> Vec<String> {
    xs.iter().map(|s| s.to_string()).collect()
}

#[test]
fn cli_full_workflow() {
    let data = tmp("wf.bmat");
    let out = tmp("wf-mi.csv");
    assert_eq!(
        cli::run(&sv(&[
            "generate", "--rows", "500", "--cols", "24", "--sparsity", "0.85",
            "--seed", "3", "--plant", "1:20:0.05", "--out", data.to_str().unwrap(),
        ])),
        0
    );
    assert_eq!(
        cli::run(&sv(&[
            "compute", "--input", data.to_str().unwrap(), "--backend", "bulk-bitpack",
            "--block-cols", "8", "--top", "5", "--out", out.to_str().unwrap(),
        ])),
        0
    );
    // strongest pair in the written matrix is the planted one
    let text = std::fs::read_to_string(&out).unwrap();
    assert_eq!(text.lines().count(), 25);
    assert_eq!(cli::run(&sv(&["help"])), 0);
    assert_eq!(cli::run(&sv(&["info"])), 0);
    assert_eq!(cli::run(&sv(&["selftest", "--rows", "80", "--cols", "8"])), 0);
    assert_ne!(cli::run(&sv(&["frobnicate"])), 0);
    assert_ne!(cli::run(&sv(&["compute", "--input", "/nonexistent.csv"])), 0);
}

#[test]
fn cli_serve_demo() {
    assert_eq!(
        cli::run(&sv(&[
            "serve", "--workers", "2", "--max-queued", "2", "--jobs", "4",
            "--block-cols", "32",
        ])),
        0
    );
}

#[test]
fn pack_then_streaming_compute_matches_in_memory() {
    // CSV -> `pack` -> v2 -> matrix-free compute over the streamed
    // file equals the same run over the in-memory CSV load, through
    // the real CLI surface end to end.
    let csv = tmp("pk.csv");
    assert_eq!(
        cli::run(&sv(&[
            "generate", "--rows", "600", "--cols", "40", "--sparsity", "0.85",
            "--seed", "9", "--plant", "2:31:0.02", "--out", csv.to_str().unwrap(),
        ])),
        0
    );
    let v2 = tmp("pk.bmat");
    assert_eq!(
        cli::run(&sv(&[
            "pack", "--input", csv.to_str().unwrap(), "--out", v2.to_str().unwrap(),
        ])),
        0
    );
    let from_csv = tmp("pk-mem-pairs.csv");
    let from_v2 = tmp("pk-strm-pairs.csv");
    for (input, out) in [(&csv, &from_csv), (&v2, &from_v2)] {
        assert_eq!(
            cli::run(&sv(&[
                "compute", "--input", input.to_str().unwrap(), "--sink", "topk:32",
                "--block-cols", "12", "--out", out.to_str().unwrap(),
            ])),
            0
        );
    }
    let mem = std::fs::read_to_string(&from_csv).unwrap();
    let strm = std::fs::read_to_string(&from_v2).unwrap();
    assert_eq!(mem, strm, "streamed v2 run must equal the in-memory run");
    assert_eq!(mem.lines().count(), 33, "header + 32 pairs");
    assert!(mem.lines().nth(1).unwrap().starts_with("col2,col31,"), "planted pair first");
    // the autotuned backend also streams
    assert_eq!(
        cli::run(&sv(&[
            "compute", "--input", v2.to_str().unwrap(), "--backend", "auto",
            "--sink", "topk:5", "--top", "3",
        ])),
        0
    );
}

#[test]
fn serve_streams_a_packed_input_file() {
    let data = tmp("serve-src.bmat");
    assert_eq!(
        cli::run(&sv(&[
            "generate", "--rows", "500", "--cols", "30", "--sparsity", "0.9",
            "--seed", "13", "--out", data.to_str().unwrap(),
        ])),
        0
    );
    assert_eq!(
        cli::run(&sv(&[
            "serve", "--workers", "2", "--max-queued", "2", "--jobs", "3",
            "--block-cols", "8", "--sink", "topk:4",
            "--input", data.to_str().unwrap(),
        ])),
        0
    );
}

#[test]
fn config_driven_compute() {
    let cfg_path = tmp("run.toml");
    std::fs::write(
        &cfg_path,
        "[run]\nbackend = \"bulk-opt\"\nworkers = 2\nblock_cols = 6\n",
    )
    .unwrap();
    let cfg = RunConfig::load(&cfg_path).unwrap();
    assert_eq!(cfg.backend, Backend::BulkOpt);
    let data = tmp("cfg.csv");
    assert_eq!(
        cli::run(&sv(&["generate", "--rows", "200", "--cols", "10", "--out", data.to_str().unwrap()])),
        0
    );
    assert_eq!(
        cli::run(&sv(&[
            "compute", "--input", data.to_str().unwrap(), "--config",
            cfg_path.to_str().unwrap(), "--top", "2",
        ])),
        0
    );
}

#[test]
fn config_rejects_typos() {
    let raw = RawConfig::parse("[run]\nbackend = \"bulk-opt\"\nworker = 2\n").unwrap();
    assert!(RunConfig::from_raw(&raw).is_err());
}

/// Persistent autotune probe cache, exercised the only way it can be:
/// across real processes (the in-process suites never set
/// `BULKMI_CACHE_DIR`, so the disk layer stays inert there). Two
/// spawned `bulkmi` runs probe once total; a doctored hardware
/// fingerprint forces a re-probe; a corrupted cache file is ignored
/// with a warning, never a panic.
#[test]
fn persistent_probe_cache_across_processes() {
    let bin = env!("CARGO_BIN_EXE_bulkmi");
    let cache_dir = tmp("probe-cache-root");
    let _ = std::fs::remove_dir_all(&cache_dir);
    let data = tmp("probe-src.bmat");
    assert_eq!(
        cli::run(&sv(&[
            "generate", "--rows", "400", "--cols", "24", "--sparsity", "0.85",
            "--seed", "17", "--out", data.to_str().unwrap(),
        ])),
        0
    );
    let run = || {
        std::process::Command::new(bin)
            .args([
                "compute", "--input", data.to_str().unwrap(), "--backend", "auto",
                "--sink", "topk:3", "--top", "0",
            ])
            .env("BULKMI_CACHE_DIR", &cache_dir)
            .output()
            .expect("spawn bulkmi")
    };

    // first process probes and persists verdict + hardware fingerprint
    let out1 = run();
    assert!(out1.status.success(), "run 1: {}", String::from_utf8_lossy(&out1.stderr));
    let cache_file = cache_dir.join("probe-cache.v1");
    let fpr_file = cache_dir.join("hardware.fpr");
    assert!(cache_file.exists(), "probe verdicts must persist");
    assert!(fpr_file.exists(), "hardware fingerprint must persist");
    let cached1 = std::fs::read(&cache_file).unwrap();
    let fpr1 = std::fs::read_to_string(&fpr_file).unwrap();

    // second process hits the disk cache: no re-probe, and the proof is
    // that the cache file is byte-identical (a probe would rewrite it
    // with a fresh stamp)
    let out2 = run();
    assert!(out2.status.success(), "run 2: {}", String::from_utf8_lossy(&out2.stderr));
    assert_eq!(
        std::fs::read(&cache_file).unwrap(),
        cached1,
        "a disk hit must not rewrite the probe cache"
    );

    // a different machine's fingerprint invalidates every verdict: the
    // next run re-probes and rewrites both files for this machine
    std::fs::write(&fpr_file, "some-other-machine\n").unwrap();
    let out3 = run();
    assert!(out3.status.success(), "run 3: {}", String::from_utf8_lossy(&out3.stderr));
    assert_eq!(
        std::fs::read_to_string(&fpr_file).unwrap(),
        fpr1,
        "re-probe must restore this machine's fingerprint"
    );
    assert_ne!(
        std::fs::read(&cache_file).unwrap(),
        cached1,
        "re-probe must rewrite the cache (fresh stamp)"
    );

    // a corrupt cache file is a warning and a fresh probe, never a
    // panic or a failure
    std::fs::write(&cache_file, b"bulkmi-probe-cache,v1\nentry,garbage\n").unwrap();
    let out4 = run();
    assert!(out4.status.success(), "run 4: {}", String::from_utf8_lossy(&out4.stderr));
    assert!(
        String::from_utf8_lossy(&out4.stderr).contains("warning"),
        "corrupt cache must warn on stderr: {}",
        String::from_utf8_lossy(&out4.stderr)
    );
    let _ = std::fs::remove_dir_all(&cache_dir);
}

#[test]
fn genomics_chain_recovers_ld() {
    let panel = GenomicsSpec { n_samples: 1500, n_markers: 120, seed: 31, ..Default::default() }
        .generate();
    let path = tmp("panel.bmat");
    io::write_bmat(&panel.dataset, &path).unwrap();
    let ds = io::read_bmat(&path).unwrap();
    let mi = compute_mi(&ds, Backend::BulkBitpack).unwrap();
    let top = top_k_pairs(&mi, panel.ld_pairs.len());
    let truth: std::collections::HashSet<(usize, usize)> =
        panel.ld_pairs.iter().copied().collect();
    let sibling = |i: usize, j: usize| {
        panel.ld_pairs.iter().any(|&(c, l)| l == i || c == i)
            && panel.ld_pairs.iter().any(|&(c, l)| l == j || c == j)
    };
    let hits =
        top.iter().filter(|p| truth.contains(&(p.i, p.j)) || sibling(p.i, p.j)).count();
    assert!(
        hits as f64 / panel.ld_pairs.len() as f64 >= 0.7,
        "only {hits}/{} LD pairs recovered",
        panel.ld_pairs.len()
    );
}

#[test]
fn graph_chain_finds_communities() {
    let graph = SbmSpec { n_nodes: 90, k: 3, p_in: 0.45, p_out: 0.02, seed: 5 }.generate();
    let mi = compute_mi(&graph.adjacency, Backend::BulkSparse).unwrap();
    let top = top_k_pairs(&mi, 50);
    let same = top
        .iter()
        .filter(|p| graph.community[p.i] == graph.community[p.j])
        .count();
    assert!(same >= 45, "only {same}/50 same-community");
}

#[test]
fn text_chain_round_trips_csv() {
    let docs = builtin_corpus();
    let ds = binarize(&docs, 2, 64);
    let path = tmp("text.csv");
    io::write_csv(&ds, &path, true).unwrap();
    let back = io::read_csv(&path).unwrap();
    assert_eq!(back.bytes(), ds.bytes());
    assert_eq!(back.names().unwrap(), ds.names().unwrap());
    let mi = compute_mi(&back, Backend::BulkOpt).unwrap();
    assert!(mi.min_value() > -1e-12);
}
