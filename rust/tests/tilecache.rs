//! Tile-cache integration: warm-cache runs must be bit-identical to
//! cold runs for every native backend x measure x sink shape on dense,
//! sparse, and tail-column datasets; hit/miss/eviction counts must be
//! exactly predictable under a capacity-bounded cache; and a second
//! identical job through the `JobService` must be served almost
//! entirely from cache.

use bulkmi::coordinator::executor::{run_plan_tiled, NativeKind, NativeProvider};
use bulkmi::coordinator::planner::plan_blocks;
use bulkmi::coordinator::progress::Progress;
use bulkmi::coordinator::service::{JobService, JobSpec, JobStatus};
use bulkmi::coordinator::tilecache::TileCache;
use bulkmi::data::colstore::{ColumnSource, InMemorySource};
use bulkmi::data::dataset::BinaryDataset;
use bulkmi::data::synth::SynthSpec;
use bulkmi::mi::backend::Backend;
use bulkmi::mi::measure::CombineKind;
use bulkmi::mi::sink::{DenseSink, MiSink, SinkData, SinkSpec, TopKSink};
use bulkmi::util::error::Result;
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bulkmi-tilecache-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// One plan execution through `run_plan_tiled` into `sink`.
fn run_into(
    src: &dyn ColumnSource,
    kind: NativeKind,
    measure: CombineKind,
    block: usize,
    workers: usize,
    tiles: Option<&TileCache>,
    sink: &mut dyn MiSink,
) -> Result<()> {
    let plan = plan_blocks(src.n_cols(), block)?;
    let provider = NativeProvider::new(src, kind);
    let progress = Progress::new(plan.tasks.len());
    run_plan_tiled(src, &plan, &provider, workers, &progress, sink, measure, tiles)
}

fn dense_run(
    src: &dyn ColumnSource,
    kind: NativeKind,
    measure: CombineKind,
    block: usize,
    tiles: Option<&TileCache>,
) -> Vec<f64> {
    let mut sink = DenseSink::new(src.n_cols());
    run_into(src, kind, measure, block, 2, tiles, &mut sink).unwrap();
    match sink.finish().unwrap().data {
        SinkData::Dense(mi) => (0..mi.dim())
            .flat_map(|i| (0..mi.dim()).map(move |j| (i, j)))
            .map(|(i, j)| mi.get(i, j))
            .collect(),
        other => panic!("dense sink returned {other:?}"),
    }
}

fn topk_run(
    src: &dyn ColumnSource,
    kind: NativeKind,
    measure: CombineKind,
    block: usize,
    tiles: Option<&TileCache>,
) -> Vec<(usize, usize, f64)> {
    let mut sink = TopKSink::global(8);
    run_into(src, kind, measure, block, 2, tiles, &mut sink).unwrap();
    match sink.finish().unwrap().data {
        SinkData::TopK(pairs) => pairs.iter().map(|p| (p.i, p.j, p.mi)).collect(),
        other => panic!("topk sink returned {other:?}"),
    }
}

/// dense (~0.3), sparse (~0.95), and a shape whose last column block is
/// a short tail (m not a multiple of the block width).
fn datasets() -> Vec<(&'static str, BinaryDataset, usize)> {
    vec![
        ("dense", SynthSpec::new(260, 20).sparsity(0.3).seed(5).generate(), 5),
        ("sparse", SynthSpec::new(260, 20).sparsity(0.95).seed(6).generate(), 5),
        ("tail", SynthSpec::new(260, 18).sparsity(0.6).seed(7).generate(), 5),
    ]
}

#[test]
fn warm_runs_are_bit_identical_to_cold_everywhere() {
    for (label, ds, block) in datasets() {
        let src = InMemorySource::new(&ds);
        let n_tasks = plan_blocks(ds.n_cols(), block).unwrap().tasks.len() as u64;
        for kind in [NativeKind::Bitpack, NativeKind::Dense, NativeKind::Sparse] {
            for measure in [CombineKind::Mi, CombineKind::GStat] {
                let cache =
                    TileCache::open(tmp(&format!("warm-{label}-{kind:?}-{measure:?}")), 1 << 30);
                let plain = dense_run(&src, kind, measure, block, None);
                let cold = dense_run(&src, kind, measure, block, Some(&cache));
                let s = cache.stats();
                assert_eq!((s.hits, s.misses), (0, n_tasks), "{label}/{kind:?}/{measure:?} cold");
                let warm = dense_run(&src, kind, measure, block, Some(&cache));
                let s = cache.stats();
                assert_eq!(
                    (s.hits, s.misses),
                    (n_tasks, n_tasks),
                    "{label}/{kind:?}/{measure:?} warm"
                );
                assert_eq!(plain, cold, "{label}/{kind:?}/{measure:?}: caching changed bits");
                assert_eq!(cold, warm, "{label}/{kind:?}/{measure:?}: a hit changed bits");
                // the same cached Grams serve a different sink shape
                let plain_top = topk_run(&src, kind, measure, block, None);
                let warm_top = topk_run(&src, kind, measure, block, Some(&cache));
                assert_eq!(plain_top, warm_top, "{label}/{kind:?}/{measure:?} topk");
                assert_eq!(cache.stats().hits, 2 * n_tasks, "topk run must be all hits");
            }
        }
    }
}

#[test]
fn tiles_are_shared_across_backends() {
    // the Gram is substrate-independent, so one backend's cold run
    // warms every other backend
    let ds = SynthSpec::new(300, 15).sparsity(0.8).seed(9).generate();
    let src = InMemorySource::new(&ds);
    let n_tasks = plan_blocks(15, 4).unwrap().tasks.len() as u64;
    let cache = TileCache::open(tmp("xbackend"), 1 << 30);
    let cold = dense_run(&src, NativeKind::Bitpack, CombineKind::Mi, 4, Some(&cache));
    assert_eq!(cache.stats().misses, n_tasks);
    for kind in [NativeKind::Dense, NativeKind::Sparse] {
        let warm = dense_run(&src, kind, CombineKind::Mi, 4, Some(&cache));
        assert_eq!(warm, cold, "{kind:?} must be served the bit-identical Gram");
    }
    let s = cache.stats();
    assert_eq!((s.hits, s.misses), (2 * n_tasks, n_tasks));
}

#[test]
fn capacity_bounded_cache_has_exact_hit_miss_eviction_counts() {
    // m = 8, block = 2: 4 equal column blocks, 10 uniform 2x2 tiles.
    // Budget holds exactly 3 tiles, single worker, deterministic plan
    // order t0..t9, LRU retention.
    let ds = SynthSpec::new(200, 8).sparsity(0.7).seed(13).generate();
    let src = InMemorySource::new(&ds);
    let one = TileCache::file_bytes(2, 2);
    let cache = TileCache::open(tmp("capacity"), 3 * one);

    // cold: every task misses and inserts; the first 7 inserts get
    // evicted again as later tiles arrive
    let cold = dense_run_serial(&src, &cache);
    let s = cache.stats();
    assert_eq!((s.hits, s.misses, s.evictions), (0, 10, 7));
    assert_eq!(cache.len(), 3, "exactly 3 tiles fit the budget");
    assert_eq!(cache.resident_bytes(), 3 * one);
    assert_eq!(s.inserted_bytes, 10 * one as u64);

    // warm, same order: the cache holds {t7, t8, t9}, but t7 was
    // already evicted by the warm insert of t0 by the time the plan
    // reaches it again — with LRU and in-order traversal every lookup
    // misses and every insert evicts exactly one tile
    let warm = dense_run_serial(&src, &cache);
    let s = cache.stats();
    assert_eq!((s.hits, s.misses, s.evictions), (0, 20, 17));
    assert_eq!(warm, cold, "thrashing must never change results");

    // a budget that fits the whole plan turns the third run into pure
    // hits with zero evictions
    let big = TileCache::open(tmp("capacity-big"), 1 << 30);
    let third = dense_run_serial(&src, &big);
    let fourth = dense_run_serial(&src, &big);
    let s = big.stats();
    assert_eq!((s.hits, s.misses, s.evictions), (10, 10, 0));
    assert_eq!(third, fourth);
}

/// Single-worker block-2 dense run: the deterministic traversal the
/// capacity test's exact counts rely on.
fn dense_run_serial(src: &dyn ColumnSource, cache: &TileCache) -> Vec<f64> {
    let mut sink = DenseSink::new(src.n_cols());
    run_into(src, NativeKind::Bitpack, CombineKind::Mi, 2, 1, Some(cache), &mut sink).unwrap();
    match sink.finish().unwrap().data {
        SinkData::Dense(mi) => (0..mi.dim())
            .flat_map(|i| (0..mi.dim()).map(move |j| (i, j)))
            .map(|(i, j)| mi.get(i, j))
            .collect(),
        other => panic!("dense sink returned {other:?}"),
    }
}

#[test]
fn second_identical_service_job_is_served_from_cache() {
    // JobService-level acceptance: two identical `tiles: true` jobs —
    // the second must report >= 90% tile-cache hits (in fact: all hits)
    let ds = SynthSpec::new(400, 24).sparsity(0.85).seed(21).generate();
    let src: Arc<dyn ColumnSource> = Arc::new(InMemorySource::new(&ds));
    let n_tasks = plan_blocks(24, 6).unwrap().tasks.len() as u64;
    let svc = JobService::new(2, 8);
    let spec = JobSpec::builder()
        .backend(Backend::BulkBitpack)
        .block_cols(6)
        .sink(SinkSpec::parse("topk:5").unwrap())
        .tiles(true)
        .build()
        .unwrap();

    let run = |spec: JobSpec| {
        let h = svc.submit_source(Arc::clone(&src), spec).unwrap();
        match svc.wait(h).unwrap() {
            JobStatus::Done(out) => out,
            other => panic!("job did not finish: {other:?}"),
        }
    };
    let first = run(spec.clone());
    let report = first.meta.tiles.expect("tiles: true must report cache stats");
    assert_eq!(report.hits + report.misses, n_tasks, "one lookup per task");

    let second = run(spec);
    let report = second.meta.tiles.expect("tiles: true must report cache stats");
    assert_eq!((report.hits, report.misses), (n_tasks, 0), "second job must be all hits");
    assert!(
        report.hits * 10 >= (report.hits + report.misses) * 9,
        ">= 90% hits required, got {report:?}"
    );
    assert_eq!(
        format!("{:?}", first.data),
        format!("{:?}", second.data),
        "cached job must produce identical output"
    );

    // without the opt-in there is no tile consultation and no report
    let off = JobSpec::builder()
        .backend(Backend::BulkBitpack)
        .block_cols(6)
        .sink(SinkSpec::parse("topk:5").unwrap())
        .build()
        .unwrap();
    assert!(run(off).meta.tiles.is_none(), "tiles default off must not report");
    svc.drain();
}
