//! Sink-engine property + acceptance tests:
//!
//! * blockwise + `DenseSink` is bit-identical to the monolithic
//!   `compute_mi` result for every native backend, across random data,
//!   block sizes and worker counts;
//! * `TopKSink` / `ThresholdSink` agree exactly with post-hoc
//!   extraction (`top_k_pairs` / `edges_above`) from the full matrix;
//! * `TileSpillSink` round-trips through disk bit for bit;
//! * a 20k-column top-k run never touches anything m x m sized
//!   (the matrix-free guarantee that motivates the whole sink layer).

// The numeric checks deliberately index by (row, col) to mirror the
// paper's pseudocode (same rationale as the crate-level allow in lib.rs).
#![allow(clippy::needless_range_loop)]

use bulkmi::coordinator::executor::NativeKind;
use bulkmi::coordinator::planner::{dense_output_bytes, matrix_free_block, plan_blocks, BlockTask};
use bulkmi::coordinator::progress::Progress;
use bulkmi::coordinator::{run_plan, NativeProvider};
use bulkmi::data::dataset::BinaryDataset;
use bulkmi::data::synth::SynthSpec;
use bulkmi::linalg::dense::Mat64;
use bulkmi::mi::backend::{compute_mi, Backend};
use bulkmi::mi::measure::CombineKind;
use bulkmi::mi::sink::{
    assemble_spilled, DenseSink, MiSink, SinkData, SinkOutput, ThresholdSink, TileSpillSink,
    TopKSink,
};
use bulkmi::mi::significance::mi_threshold_for_pvalue;
use bulkmi::mi::topk::{edges_above, top_k_pairs, MiPair};
use bulkmi::util::error::Result as BResult;
use bulkmi::util::prop::{gen, prop_check, Config};

fn run_sink(
    ds: &BinaryDataset,
    kind: NativeKind,
    block: usize,
    workers: usize,
    sink: &mut dyn MiSink,
) -> BResult<SinkOutput> {
    let plan = plan_blocks(ds.n_cols(), block)?;
    let provider = NativeProvider::new(ds, kind);
    let progress = Progress::new(plan.tasks.len());
    run_plan(ds, &plan, &provider, workers, &progress, sink, CombineKind::Mi)?;
    sink.finish()
}

/// Acceptance: blockwise `DenseSink` == monolithic `compute_mi`, bit
/// for bit, for every native backend.
#[test]
fn prop_dense_sink_bit_identical_to_monolithic() {
    let backends = [
        (Backend::Pairwise, NativeKind::Bitpack),
        (Backend::BulkBasic, NativeKind::Dense),
        (Backend::BulkOpt, NativeKind::Dense),
        (Backend::BulkSparse, NativeKind::Sparse),
        (Backend::BulkBitpack, NativeKind::Bitpack),
    ];
    prop_check(
        "blockwise DenseSink == monolithic compute_mi",
        Config::with_cases(8),
        |rng| {
            let (n, m, bytes) = gen::binary_matrix(rng, 90, 24);
            let block = gen::int_in(rng, 1, 26);
            let workers = gen::int_in(rng, 1, 4);
            (n, m, bytes, block, workers)
        },
        |(n, m, bytes, block, workers)| {
            let ds = BinaryDataset::new(*n, *m, bytes.clone()).map_err(|e| e.to_string())?;
            for (backend, kind) in backends {
                let mono = compute_mi(&ds, backend).map_err(|e| e.to_string())?;
                let mut sink = DenseSink::new(*m);
                let out = run_sink(&ds, kind, *block, *workers, &mut sink)
                    .map_err(|e| e.to_string())?;
                let SinkData::Dense(got) = out.data else {
                    return Err("dense sink returned non-dense output".into());
                };
                let diff = got.max_abs_diff(&mono);
                if diff != 0.0 {
                    return Err(format!(
                        "{backend} block={block} workers={workers}: diff {diff}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_topk_sink_matches_posthoc_extraction() {
    prop_check(
        "TopKSink == top_k_pairs(full)",
        Config::with_cases(12),
        |rng| {
            let (n, m, bytes) = gen::binary_matrix(rng, 100, 20);
            let block = gen::int_in(rng, 1, 21);
            let k = gen::int_in(rng, 1, 40);
            (n, m, bytes, block, k)
        },
        |(n, m, bytes, block, k)| {
            let ds = BinaryDataset::new(*n, *m, bytes.clone()).map_err(|e| e.to_string())?;
            let full = compute_mi(&ds, Backend::BulkBitpack).unwrap();
            let want = top_k_pairs(&full, *k);
            let mut sink = TopKSink::global(*k);
            let out = run_sink(&ds, NativeKind::Bitpack, *block, 2, &mut sink)
                .map_err(|e| e.to_string())?;
            let SinkData::TopK(got) = out.data else {
                return Err("wrong output kind".into());
            };
            if got.len() != want.len() {
                return Err(format!("{} pairs, wanted {}", got.len(), want.len()));
            }
            for (g, w) in got.iter().zip(&want) {
                if (g.i, g.j) != (w.i, w.j) || g.mi != w.mi {
                    return Err(format!("got {g:?}, wanted {w:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_threshold_sink_matches_edges_above() {
    prop_check(
        "ThresholdSink == edges_above(full)",
        Config::with_cases(12),
        |rng| {
            let (n, m, bytes) = gen::binary_matrix(rng, 100, 18);
            let block = gen::int_in(rng, 1, 19);
            (n, m, bytes, block)
        },
        |(n, m, bytes, block)| {
            let ds = BinaryDataset::new(*n, *m, bytes.clone()).map_err(|e| e.to_string())?;
            let full = compute_mi(&ds, Backend::BulkBitpack).unwrap();
            for threshold in [0.0, 0.01, 0.1, 0.5] {
                let want = edges_above(&full, threshold);
                let mut sink = ThresholdSink::by_mi(threshold);
                let out = run_sink(&ds, NativeKind::Bitpack, *block, 2, &mut sink)
                    .map_err(|e| e.to_string())?;
                let SinkData::Sparse(sp) = out.data else {
                    return Err("wrong output kind".into());
                };
                if sp.pairs.len() != want.len() {
                    return Err(format!(
                        "t={threshold}: {} edges, wanted {}",
                        sp.pairs.len(),
                        want.len()
                    ));
                }
                for (g, w) in sp.pairs.iter().zip(&want) {
                    if (g.i, g.j) != (w.i, w.j) || g.mi != w.mi {
                        return Err(format!("t={threshold}: got {g:?}, wanted {w:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn per_column_topk_matches_posthoc() {
    let ds = SynthSpec::new(400, 15).sparsity(0.6).seed(3).plant(2, 11, 0.05).generate();
    let full = compute_mi(&ds, Backend::BulkBitpack).unwrap();
    let k = 4;
    let mut sink = TopKSink::per_column(15, k);
    let out = run_sink(&ds, NativeKind::Bitpack, 4, 2, &mut sink).unwrap();
    let SinkData::TopKPerColumn(cols) = out.data else { panic!("wrong output kind") };
    assert_eq!(cols.len(), 15);
    for c in 0..15 {
        // post-hoc: all pairs involving c, ranked like top_k_pairs
        let mut want: Vec<MiPair> = top_k_pairs(&full, usize::MAX)
            .into_iter()
            .filter(|p| p.i == c || p.j == c)
            .collect();
        want.truncate(k);
        assert_eq!(cols[c].len(), want.len(), "column {c}");
        for (g, w) in cols[c].iter().zip(&want) {
            assert_eq!((g.i, g.j), (w.i, w.j), "column {c}");
            assert_eq!(g.mi, w.mi, "column {c}");
        }
    }
}

#[test]
fn pvalue_threshold_sink_matches_derived_cutoff() {
    let ds = SynthSpec::new(800, 12).sparsity(0.6).seed(7).plant(0, 5, 0.02).generate();
    let full = compute_mi(&ds, Backend::BulkBitpack).unwrap();
    let p = 1e-4;
    let cutoff = mi_threshold_for_pvalue(p, 800).unwrap();
    let want = edges_above(&full, cutoff);
    let mut sink = ThresholdSink::by_pvalue(p, 800).unwrap();
    assert_eq!(sink.threshold(), cutoff);
    let out = run_sink(&ds, NativeKind::Bitpack, 5, 2, &mut sink).unwrap();
    let SinkData::Sparse(sp) = out.data else { panic!("wrong output kind") };
    assert_eq!(sp.pvalue, Some(p));
    assert_eq!(sp.pairs.len(), want.len());
    // the planted pair survives the significance screen
    assert!(sp.pairs.iter().any(|e| (e.i, e.j) == (0, 5)));
}

#[test]
fn spill_sink_round_trips_through_disk() {
    let dir = std::env::temp_dir().join(format!("bulkmi-sinks-spill-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let ds = SynthSpec::new(300, 17).sparsity(0.8).seed(11).generate();
    let full = compute_mi(&ds, Backend::BulkBitpack).unwrap();
    let mut sink = TileSpillSink::new(&dir, 17).unwrap();
    let out = run_sink(&ds, NativeKind::Bitpack, 5, 3, &mut sink).unwrap();
    let SinkData::Spilled(info) = out.data else { panic!("wrong output kind") };
    let plan = plan_blocks(17, 5).unwrap();
    assert_eq!(info.tiles, plan.tasks.len());
    let assembled = assemble_spilled(&dir).unwrap();
    assert_eq!(assembled.max_abs_diff(&full), 0.0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Records the largest block that ever reaches the sink — the proof
/// that the result path is matrix-free.
struct BlockAudit<S> {
    inner: S,
    max_cells: usize,
    blocks: usize,
}

impl<S: MiSink> MiSink for BlockAudit<S> {
    fn consume_block(&mut self, t: &BlockTask, block: &Mat64) -> BResult<()> {
        self.max_cells = self.max_cells.max(block.rows() * block.cols());
        self.blocks += 1;
        self.inner.consume_block(t, block)
    }

    fn finish(&mut self) -> BResult<SinkOutput> {
        self.inner.finish()
    }
}

/// Acceptance: top-1000 pairs of a 20k-column dataset without ever
/// allocating the m x m dense matrix. The dense output would be
/// 20_000^2 * 8 B = 3.2 GB; the audit proves the result path only ever
/// held one block (<= block^2 cells) plus the O(k) heap.
#[test]
fn topk_20k_columns_without_dense_matrix() {
    let m = 20_000;
    let n = 256;
    let ds = SynthSpec::new(n, m).sparsity(0.95).seed(21).plant(17, 15_011, 0.0).generate();
    let block = matrix_free_block(n, m, 64 << 20);
    assert!(block < m, "20k columns must be planned blockwise");
    let plan = plan_blocks(m, block).unwrap();
    assert!(plan.tasks.len() > 1);

    let provider = NativeProvider::new(&ds, NativeKind::Bitpack);
    let mut audit = BlockAudit { inner: TopKSink::global(1000), max_cells: 0, blocks: 0 };
    let progress = Progress::new(plan.tasks.len());
    run_plan(&ds, &plan, &provider, 4, &progress, &mut audit, CombineKind::Mi).unwrap();

    // matrix-free: nothing m x m sized ever existed on the result path
    assert_eq!(audit.blocks, plan.tasks.len());
    assert!(audit.max_cells <= block * block);
    assert!(
        audit.max_cells * 8 * 50 < dense_output_bytes(m),
        "largest block ({} cells) must be far below the dense matrix",
        audit.max_cells
    );

    let SinkData::TopK(pairs) = audit.finish().unwrap().data else { panic!("wrong output") };
    assert_eq!(pairs.len(), 1000);
    assert_eq!(
        (pairs[0].i, pairs[0].j),
        (17, 15_011),
        "the planted exact copy must rank first"
    );
    assert!(pairs[0].mi > pairs[1].mi * 2.0, "planted pair should dominate noise");
}
