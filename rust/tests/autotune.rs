//! `--backend auto` acceptance tests:
//!
//! * auto returns **bit-identical** MI to every fixed native backend on
//!   both dense and 1%-sparse data (all native backends combine the
//!   same integer counts, so equality is exact, not approximate);
//! * the autotuner never commits to a backend whose probed Gram
//!   throughput is below the best fixed choice on the probe block;
//! * an auto job through the service records what it chose in the
//!   output's `SinkMeta`.

use bulkmi::coordinator::service::{JobService, JobSpec, JobStatus};
use bulkmi::data::synth::SynthSpec;
use bulkmi::mi::autotune::{autotune, eligible};
use bulkmi::mi::backend::{compute_mi, compute_mi_with, Backend};
use bulkmi::mi::sink::{SinkData, SinkSpec};

#[test]
fn auto_bit_identical_to_every_fixed_backend() {
    // dense (50% ones) and 1%-sparse synth data
    for &(sparsity, seed) in &[(0.5f64, 11u64), (0.99, 12)] {
        let ds = SynthSpec::new(600, 30).sparsity(sparsity).seed(seed).generate();
        let auto = compute_mi(&ds, Backend::Auto).unwrap();
        for fixed in eligible() {
            let want = compute_mi(&ds, fixed).unwrap();
            assert_eq!(
                auto.max_abs_diff(&want),
                0.0,
                "sparsity={sparsity}: auto != {fixed}"
            );
        }
        // and workers don't change the auto result either
        let auto4 = compute_mi_with(&ds, Backend::Auto, 4).unwrap();
        assert_eq!(auto.max_abs_diff(&auto4), 0.0);
    }
}

#[test]
fn probe_winner_is_never_below_best_fixed_throughput() {
    for &(sparsity, seed) in &[(0.5f64, 21u64), (0.99, 22)] {
        let ds = SynthSpec::new(2000, 48).sparsity(sparsity).seed(seed).generate();
        let report = autotune(&ds).unwrap();
        let chosen = report
            .candidates
            .iter()
            .find(|c| c.backend == report.chosen)
            .expect("chosen backend was probed");
        for candidate in &report.candidates {
            assert!(
                chosen.throughput >= candidate.throughput,
                "auto chose {} ({:.3e} cells/s) below {} ({:.3e}): {}",
                report.chosen,
                chosen.throughput,
                candidate.backend,
                candidate.throughput,
                report.summary()
            );
        }
        assert_eq!(report.candidates.len(), eligible().len());
        assert!((0.0..=1.0).contains(&report.density));
    }
}

#[test]
fn auto_job_records_choice_in_sink_meta() {
    let svc = JobService::new(2, 4);
    let ds = SynthSpec::new(500, 24).sparsity(0.9).seed(33).plant(1, 7, 0.02).generate();
    let full = compute_mi(&ds, Backend::BulkBitpack).unwrap();
    let spec = JobSpec::builder()
        .backend(Backend::Auto)
        .block_cols(8)
        .sink(SinkSpec::TopK { k: 3, per_column: false })
        .build()
        .unwrap();
    let h = svc.submit(ds, spec).unwrap();
    let JobStatus::Done(out) = svc.wait(h).unwrap() else {
        panic!("auto job failed")
    };
    // metadata: what ran, what was asked, what the probe saw
    assert_eq!(out.meta.requested_backend.as_deref(), Some("auto"));
    let chosen = out.meta.backend.as_deref().expect("resolved backend recorded");
    assert!(
        eligible().iter().any(|b| b.name() == chosen),
        "auto resolved to unexpected backend '{chosen}'"
    );
    assert!(out.meta.kernel.is_some(), "gram kernel recorded");
    let sizing = out.meta.sizing.as_ref().expect("block sizing recorded");
    assert_eq!((sizing.block_cols, sizing.source), (8, "explicit"));
    let probe = out.meta.probe.as_ref().expect("probe report attached");
    assert_eq!(probe.chosen.name(), chosen);
    assert!(out.summary().contains(chosen), "summary names the backend");
    // ... and the result is still exact
    let SinkData::TopK(pairs) = out.data else { panic!("wrong output kind") };
    let want = bulkmi::mi::topk::top_k_pairs(&full, 3);
    assert_eq!((pairs[0].i, pairs[0].j), (want[0].i, want[0].j));
    assert_eq!(pairs[0].mi, want[0].mi);
}

/// The serve-workload acceptance case for the probe cache: the second
/// identically-shaped auto job reuses the first job's probe verdict
/// (same choice, the *original* timings, `cached` set) instead of
/// re-timing, and both jobs record a probe-throughput block sizing.
#[test]
fn probe_cache_reused_across_jobs() {
    let svc = JobService::new(1, 4);
    // shape unique to this test so parallel tests cannot pre-seed the key
    let ds = SynthSpec::new(777, 26).sparsity(0.8).seed(55).generate();
    let spec = JobSpec::builder()
        .backend(Backend::Auto)
        .sink(SinkSpec::TopK { k: 2, per_column: false })
        .build()
        .unwrap();
    let h1 = svc.submit(ds.clone(), spec.clone()).unwrap();
    let JobStatus::Done(first) = svc.wait(h1).unwrap() else { panic!() };
    let h2 = svc.submit(ds, spec).unwrap();
    let JobStatus::Done(second) = svc.wait(h2).unwrap() else { panic!() };

    let p1 = first.meta.probe.as_ref().expect("first probe recorded");
    let p2 = second.meta.probe.as_ref().expect("second probe recorded");
    assert!(!p1.cached, "first job of this shape times a fresh probe");
    assert!(p2.cached, "second identically-shaped job reuses the verdict");
    assert_eq!(p2.chosen, p1.chosen);
    assert_eq!(p1.candidates.len(), p2.candidates.len());
    for (a, b) in p1.candidates.iter().zip(&p2.candidates) {
        assert_eq!(a.backend, b.backend);
        assert_eq!(a.secs, b.secs, "cached report carries the original timings");
        assert_eq!(a.throughput, b.throughput);
    }
    for out in [&first, &second] {
        let sizing = out.meta.sizing.as_ref().expect("sizing recorded");
        assert_eq!(sizing.source, "probe-throughput");
        assert!(sizing.block_cols >= 1 && sizing.block_cols <= 26);
    }
}

#[test]
fn fixed_backend_jobs_record_plain_meta() {
    let svc = JobService::new(1, 2);
    let ds = SynthSpec::new(120, 10).sparsity(0.7).seed(5).generate();
    let h = svc.submit(ds, JobSpec::default()).unwrap();
    let JobStatus::Done(out) = svc.wait(h).unwrap() else { panic!() };
    assert_eq!(out.meta.backend.as_deref(), Some("bulk-bitpack"));
    assert_eq!(out.meta.requested_backend.as_deref(), Some("bulk-bitpack"));
    assert!(out.meta.probe.is_none(), "fixed backends don't probe");
}

#[test]
fn xla_jobs_are_rejected_by_the_builder() {
    // the validating builder is the only construction path for
    // external callers, so non-native specs never reach submit
    let err = JobSpec::builder().backend(Backend::Xla).build();
    assert!(err.is_err());
}
