//! Oracle suite for the pluggable combine layer: every [`CombineKind`]
//! on every native backend must match a naive per-pair reference
//! (row-scan contingency counts + textbook formulas, written
//! independently of `mi::measure`) to 1e-12 precision, on dense,
//! 1%-sparse, constant-column and 0/1-row edge datasets — plus the
//! measure invariants (symmetry, ranges, zero under exact
//! independence) and the `pvalue:` sink's measure-aware χ²₁
//! conversion.

use bulkmi::data::dataset::BinaryDataset;
use bulkmi::data::synth::SynthSpec;
use bulkmi::mi::autotune;
use bulkmi::mi::backend::{compute_measure_with, Backend};
use bulkmi::mi::measure::CombineKind;
use bulkmi::mi::significance::mi_threshold_for_pvalue;
use bulkmi::mi::sink::SinkSpec;
use bulkmi::mi::MiMatrix;

/// The backends that must agree with the oracle: every implementation
/// that needs no XLA artifacts.
fn native_backends() -> Vec<Backend> {
    Backend::ALL.into_iter().filter(|b| b.is_native()).collect()
}

// ---------------------------------------------------------------------
// The naive reference oracle
// ---------------------------------------------------------------------

/// 2x2 contingency counts of one column pair via a full row scan —
/// the `pairwise.rs`-style reference path, no Gram anywhere.
fn pair_counts(ds: &BinaryDataset, i: usize, j: usize) -> (u64, u64, u64, u64) {
    let (mut n11, mut n10, mut n01, mut n00) = (0u64, 0u64, 0u64, 0u64);
    for r in 0..ds.n_rows() {
        match (ds.get(r, i), ds.get(r, j)) {
            (1, 1) => n11 += 1,
            (1, 0) => n10 += 1,
            (0, 1) => n01 += 1,
            _ => n00 += 1,
        }
    }
    (n11, n10, n01, n00)
}

fn binary_entropy(p: f64) -> f64 {
    if p <= 0.0 || p >= 1.0 {
        0.0
    } else {
        -p * p.log2() - (1.0 - p) * (1.0 - p).log2()
    }
}

/// Textbook formulas straight from the definitions (probabilities for
/// MI, nats for G, expected counts for χ²) — deliberately *not* the
/// evaluation order `mi::measure` uses, so agreement is a real check.
fn oracle(kind: CombineKind, n11: u64, n10: u64, n01: u64, n00: u64) -> f64 {
    let n = (n11 + n10 + n01 + n00) as f64;
    if n <= 0.0 {
        return 0.0;
    }
    let (f11, f10, f01, f00) = (n11 as f64, n10 as f64, n01 as f64, n00 as f64);
    let (rx1, rx0) = (f11 + f10, f01 + f00); // X marginal counts
    let (cy1, cy0) = (f11 + f01, f10 + f00); // Y marginal counts
    let mi = {
        let cell = |fxy: f64, fx: f64, fy: f64| {
            if fxy > 0.0 {
                let pxy = fxy / n;
                pxy * (pxy / ((fx / n) * (fy / n))).log2()
            } else {
                0.0
            }
        };
        cell(f11, rx1, cy1) + cell(f10, rx1, cy0) + cell(f01, rx0, cy1) + cell(f00, rx0, cy0)
    };
    match kind {
        CombineKind::Mi => mi,
        CombineKind::Nmi => {
            let denom = binary_entropy(rx1 / n).min(binary_entropy(cy1 / n));
            if denom > 0.0 {
                (mi / denom).clamp(0.0, 1.0)
            } else {
                0.0
            }
        }
        CombineKind::Vi => {
            (binary_entropy(rx1 / n) + binary_entropy(cy1 / n) - 2.0 * mi).max(0.0)
        }
        CombineKind::GStat => {
            // G in nats, straight from the log-likelihood ratio
            let cell = |fxy: f64, fx: f64, fy: f64| {
                if fxy > 0.0 {
                    fxy * (fxy * n / (fx * fy)).ln()
                } else {
                    0.0
                }
            };
            2.0 * (cell(f11, rx1, cy1)
                + cell(f10, rx1, cy0)
                + cell(f01, rx0, cy1)
                + cell(f00, rx0, cy0))
        }
        CombineKind::Chi2 => {
            if rx1 <= 0.0 || rx0 <= 0.0 || cy1 <= 0.0 || cy0 <= 0.0 {
                return 0.0; // a constant column: no deviation possible
            }
            let cell = |obs: f64, fx: f64, fy: f64| {
                let e = fx * fy / n;
                (obs - e).powi(2) / e
            };
            cell(f11, rx1, cy1) + cell(f10, rx1, cy0) + cell(f01, rx0, cy1) + cell(f00, rx0, cy0)
        }
        CombineKind::Phi => {
            let denom = (rx1 * rx0 * cy1 * cy0).sqrt();
            if denom > 0.0 {
                (f11 * f00 - f10 * f01) / denom
            } else {
                0.0
            }
        }
        CombineKind::Jaccard => {
            let union = f11 + f10 + f01;
            if union > 0.0 { f11 / union } else { 0.0 }
        }
        CombineKind::Ochiai => {
            let denom = (rx1 * cy1).sqrt();
            if denom > 0.0 { f11 / denom } else { 0.0 }
        }
    }
}

/// 1e-12 precision: absolute for O(1)-scaled measures, relative for the
/// statistics whose magnitude grows with n (gstat, chi2).
fn tol(v: f64) -> f64 {
    1e-12 * v.abs().max(1.0)
}

fn check_against_oracle(ds: &BinaryDataset, backend: Backend, workers: usize) {
    let m = ds.n_cols();
    for kind in CombineKind::ALL {
        let got = compute_measure_with(ds, backend, workers, kind).unwrap();
        assert_eq!(got.dim(), m);
        for i in 0..m {
            for j in 0..m {
                let (n11, n10, n01, n00) = pair_counts(ds, i, j);
                let want = oracle(kind, n11, n10, n01, n00);
                let diff = (got.get(i, j) - want).abs();
                assert!(
                    diff <= tol(want),
                    "{kind} on {backend} ({i},{j}): got {} want {want} (diff {diff:.3e})",
                    got.get(i, j)
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Property tests: every measure x every native backend x dataset shapes
// ---------------------------------------------------------------------

#[test]
fn dense_dataset_matches_oracle_on_every_backend() {
    let ds = SynthSpec::new(300, 12).sparsity(0.5).seed(41).plant(0, 7, 0.05).generate();
    for backend in native_backends() {
        check_against_oracle(&ds, backend, 1);
    }
}

#[test]
fn one_percent_sparse_matches_oracle_on_every_backend() {
    let ds = SynthSpec::new(500, 10).sparsity(0.99).seed(42).generate();
    for backend in native_backends() {
        check_against_oracle(&ds, backend, 1);
    }
}

#[test]
fn constant_columns_match_oracle_on_every_backend() {
    // col 0 all-zero, col 1 all-one, col 2 alternating, col 3 sparse
    let n = 48;
    let mut data = vec![0u8; n * 4];
    for r in 0..n {
        data[r * 4 + 1] = 1;
        data[r * 4 + 2] = (r % 2) as u8;
        data[r * 4 + 3] = u8::from(r % 5 == 0);
    }
    let ds = BinaryDataset::new(n, 4, data).unwrap();
    for backend in native_backends() {
        check_against_oracle(&ds, backend, 1);
    }
}

#[test]
fn one_row_edge_dataset_matches_oracle() {
    // a single observation: every variable is constant, every
    // dependence measure must be 0 and every similarity well-defined
    let ds = BinaryDataset::new(1, 5, vec![1, 0, 1, 1, 0]).unwrap();
    for backend in native_backends() {
        check_against_oracle(&ds, backend, 1);
    }
    let jac = compute_measure_with(&ds, Backend::BulkBitpack, 1, CombineKind::Jaccard).unwrap();
    assert_eq!(jac.get(0, 2), 1.0, "both ones in the single row co-occur");
    assert_eq!(jac.get(1, 4), 0.0, "empty union is 0, not NaN");
}

#[test]
fn zero_one_row_extremes_match_oracle() {
    // rows of all-zeros and all-ones alongside mixed rows
    let n = 6;
    let rows: [[u8; 3]; 6] = [[0, 0, 0], [1, 1, 1], [0, 0, 0], [1, 0, 1], [1, 1, 1], [0, 1, 0]];
    let ds = BinaryDataset::new(n, 3, rows.concat()).unwrap();
    for backend in native_backends() {
        check_against_oracle(&ds, backend, 1);
    }
}

#[test]
fn zero_row_dataset_is_a_clean_error() {
    let ds = BinaryDataset::new(0, 3, vec![]).unwrap();
    for kind in CombineKind::ALL {
        assert!(compute_measure_with(&ds, Backend::BulkBitpack, 1, kind).is_err(), "{kind}");
    }
}

#[test]
fn parallel_blockwise_is_bit_identical_to_serial() {
    let ds = SynthSpec::new(400, 21).sparsity(0.8).seed(43).generate();
    for kind in CombineKind::ALL {
        let serial = compute_measure_with(&ds, Backend::BulkBitpack, 1, kind).unwrap();
        for workers in [2, 5] {
            let par = compute_measure_with(&ds, Backend::BulkBitpack, workers, kind).unwrap();
            assert_eq!(par.max_abs_diff(&serial), 0.0, "{kind} workers={workers}");
        }
    }
}

// ---------------------------------------------------------------------
// Invariants
// ---------------------------------------------------------------------

fn matrix_for(kind: CombineKind, ds: &BinaryDataset) -> MiMatrix {
    compute_measure_with(ds, Backend::BulkBitpack, 2, kind).unwrap()
}

#[test]
fn every_measure_is_exactly_symmetric() {
    let ds = SynthSpec::new(250, 15).sparsity(0.6).seed(44).generate();
    for kind in CombineKind::ALL {
        let mat = matrix_for(kind, &ds);
        assert_eq!(mat.max_asymmetry(), 0.0, "{kind}: mirror writes must be bit-identical");
    }
}

#[test]
fn measure_ranges_hold() {
    let ds = SynthSpec::new(350, 14).sparsity(0.7).seed(45).plant(1, 9, 0.01).generate();
    let in_range = |kind: CombineKind, lo: f64, hi: f64| {
        let mat = matrix_for(kind, &ds);
        for &v in mat.data() {
            assert!((lo..=hi).contains(&v) && v.is_finite(), "{kind}: {v} outside [{lo}, {hi}]");
        }
    };
    in_range(CombineKind::Nmi, 0.0, 1.0);
    in_range(CombineKind::Jaccard, 0.0, 1.0);
    in_range(CombineKind::Ochiai, 0.0, 1.0);
    in_range(CombineKind::Phi, -1.0, 1.0);
    in_range(CombineKind::Vi, 0.0, f64::INFINITY);
    in_range(CombineKind::GStat, 0.0, f64::INFINITY);
    in_range(CombineKind::Chi2, 0.0, f64::INFINITY);
}

#[test]
fn exactly_independent_columns_are_zero() {
    // 8 rows where col 0 = first half, col 1 = parity: every joint
    // cell holds exactly n/4 rows, so independence is exact, not
    // merely asymptotic
    let mut data = vec![0u8; 16];
    for r in 0..8 {
        data[r * 2] = u8::from(r < 4);
        data[r * 2 + 1] = (r % 2) as u8;
    }
    let ds = BinaryDataset::new(8, 2, data).unwrap();
    for kind in [
        CombineKind::Mi,
        CombineKind::Nmi,
        CombineKind::GStat,
        CombineKind::Chi2,
        CombineKind::Phi,
    ] {
        let mat = matrix_for(kind, &ds);
        assert!(mat.get(0, 1).abs() < 1e-12, "{kind}: {} on independent pair", mat.get(0, 1));
    }
    // similarity coefficients are positive under independence: they
    // measure overlap, not dependence
    assert!(matrix_for(CombineKind::Jaccard, &ds).get(0, 1) > 0.0);
    assert!(matrix_for(CombineKind::Ochiai, &ds).get(0, 1) > 0.0);
}

#[test]
fn vi_is_zero_iff_columns_determine_each_other() {
    let ds = SynthSpec::new(600, 6).sparsity(0.6).seed(46).plant(0, 5, 0.0).generate();
    let vi = matrix_for(CombineKind::Vi, &ds);
    assert!(vi.get(0, 5).abs() < 1e-12, "planted copy: VI = 0");
    for i in 0..6 {
        assert!(vi.get(i, i).abs() < 1e-12, "VI(X,X) = 0");
    }
    assert!(vi.get(1, 2) > 0.1, "independent pair: VI far from 0");
}

// ---------------------------------------------------------------------
// pvalue sink: the χ²₁ conversion is measure-aware
// ---------------------------------------------------------------------

#[test]
fn pvalue_cutoff_round_trips_the_documented_example() {
    // the significance.rs doc example: P = 0.01 over n = 10_000 rows
    let spec = SinkSpec::parse("pvalue:0.01").unwrap();
    let _sink = spec.build_for(50, 10_000, CombineKind::Mi).unwrap();
    let threshold = mi_threshold_for_pvalue(0.01, 10_000).unwrap();
    let g = 2.0 * 10_000.0 * std::f64::consts::LN_2 * threshold;
    assert!((g - 6.635).abs() < 0.01, "chi²₁ 1% critical value, got G = {g}");
    // under gstat the same spec applies the critical value directly:
    // consuming a gstat matrix with it keeps exactly the pairs whose
    // MI-threshold counterpart keeps under mi (same test, same null)
    let ds = SynthSpec::new(800, 8).sparsity(0.6).seed(47).plant(0, 3, 0.05).generate();
    let mi = matrix_for(CombineKind::Mi, &ds);
    let gstat = matrix_for(CombineKind::GStat, &ds);
    let t_mi = mi_threshold_for_pvalue(0.01, 800).unwrap();
    let t_g = 2.0 * 800.0 * std::f64::consts::LN_2 * t_mi;
    for i in 0..8 {
        for j in (i + 1)..8 {
            assert_eq!(
                mi.get(i, j) >= t_mi,
                gstat.get(i, j) >= t_g,
                "({i},{j}): mi and gstat cutoffs must agree on survivors"
            );
        }
    }
}

#[test]
fn pvalue_sink_errors_cleanly_for_measures_without_a_null() {
    let spec = SinkSpec::parse("pvalue:0.01").unwrap();
    for kind in CombineKind::ALL {
        let built = spec.build_for(10, 500, kind);
        if kind.supports_pvalue_sink() {
            assert!(built.is_ok(), "{kind} should support pvalue:");
        } else {
            let err = built.err().expect("clean Err, not a panic");
            assert!(err.to_string().contains("asymptotic null"), "{kind}: {err}");
        }
    }
}

// ---------------------------------------------------------------------
// Autotuner: combine-stage timings per measure (acceptance criterion)
// ---------------------------------------------------------------------

#[test]
fn probe_report_carries_combine_timings_for_every_measure() {
    let ds = SynthSpec::new(2048, 32).sparsity(0.8).seed(48).generate();
    let report = autotune::autotune_uncached(&ds).unwrap();
    assert_eq!(report.combine.len(), CombineKind::ALL.len());
    for kind in CombineKind::ALL {
        let secs = report.combine_secs(kind).expect("one timing per probed measure");
        assert!(secs > 0.0 && secs.is_finite(), "{kind}: secs = {secs}");
    }
    // the timings travel with the verdict into the cache path too
    bulkmi::mi::autotune::clear_probe_cache();
    let fresh = autotune::autotune(&ds).unwrap();
    assert_eq!(fresh.combine.len(), CombineKind::ALL.len());
    let cached = autotune::autotune(&ds).unwrap();
    assert!(cached.cached);
    assert_eq!(cached.combine.len(), CombineKind::ALL.len());
}
