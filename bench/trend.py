#!/usr/bin/env python3
"""Fold BENCH_*.json artifacts into per-entry time series and flag drift.

CI uploads one ``BENCH_<host>.json`` per perf-smoke run (see
``bench/README.md``). Download a stack of those artifacts into a
directory tree and point this script at it to get, per ``(host, entry)``
pair, the ordered series of scalar-normalized throughput (``rel``; the
absolute ``cells_per_sec`` is the fallback for entries without a ratio)
and a drift verdict: the latest value against the median of the prior
runs. Out-of-core entries (``oocgram/...``) additionally trend their
``bytes_read`` counter as a separate series, where drift points the
other way: reading *more* bytes than the prior median is the
regression.

Stdlib only — no third-party imports — so it runs anywhere CI's python3
does. Non-gating by default (always exits 0 unless ``--strict``): the
hard perf gate stays ``bulkmi bench --baseline``; this is the trend
companion that shows slow regressions creeping under the gate's
tolerance.

Usage:
    python3 bench/trend.py DIR [DIR ...] [--threshold 0.15]
                           [--csv OUT.csv] [--strict]

Runs are ordered by file modification time, which artifact downloads
preserve per run directory; identical mtimes fall back to path order.
"""

import argparse
import glob
import json
import os
import statistics
import sys


def find_runs(dirs):
    """Collect parsed BENCH_*.json docs, oldest first."""
    runs = []
    for d in dirs:
        pattern = os.path.join(d, "**", "BENCH_*.json")
        for path in sorted(glob.glob(pattern, recursive=True)):
            try:
                with open(path, encoding="utf-8") as f:
                    doc = json.load(f)
            except (OSError, json.JSONDecodeError) as e:
                print(f"warn: skipping {path}: {e}", file=sys.stderr)
                continue
            runs.append(
                {
                    "path": path,
                    "mtime": os.path.getmtime(path),
                    "host": doc.get("host", "?"),
                    "results": doc.get("results", []),
                }
            )
    runs.sort(key=lambda r: (r["mtime"], r["path"]))
    return runs


def build_series(runs):
    """{(host, entry name, unit): [(path, metric), ...]} in run order.

    The unit is part of the key so a series never mixes scalar-relative
    ratios (~1.0) with absolute cells/sec (~1e9) — an entry that gains
    or loses its scalar reference across runs starts a separate series
    instead of producing a nonsense median.
    """
    series = {}
    for run in runs:
        for entry in run["results"]:
            name = entry.get("name", "?")
            rel = entry.get("rel")
            cps = entry.get("cells_per_sec")
            # out-of-core entries also carry a bytes_read counter; track
            # it as its own series (drift direction inverts: more bytes
            # read is the regression)
            bytes_read = entry.get("bytes_read")
            if bytes_read is not None and bytes_read > 0:
                key = (run["host"], name, "bytes")
                series.setdefault(key, []).append((run["path"], float(bytes_read)))
            metric = rel if rel is not None else cps
            if metric is None or metric <= 0:
                continue  # probe-style entries carry no throughput
            unit = "rel" if rel is not None else "cells/s"
            key = (run["host"], name, unit)
            series.setdefault(key, []).append((run["path"], metric))
    return series


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("dirs", nargs="+", help="directories holding BENCH_*.json artifacts")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.15,
        help="flag when the latest value is this fraction below the prior median",
    )
    ap.add_argument("--csv", help="also write the full series as CSV")
    ap.add_argument(
        "--strict",
        action="store_true",
        help="exit 1 when anything drifted (default: report only)",
    )
    args = ap.parse_args(argv)

    runs = find_runs(args.dirs)
    if not runs:
        print("no BENCH_*.json artifacts found — nothing to trend")
        return 0
    print(f"{len(runs)} bench run(s) across {len(args.dirs)} dir(s)\n")

    series = build_series(runs)
    flagged = []
    rows = []
    for (host, name, unit), points in sorted(series.items()):
        vals = [m for (_, m) in points]
        latest = vals[-1]
        line = f"{host:<30} {name:<30} n={len(vals):<3} latest={latest:.4g} {unit}"
        prior = vals[:-1]
        if prior:
            base = statistics.median(prior)
            drift = latest / base - 1.0 if base > 0 else 0.0
            line += f" median={base:.4g} drift={drift:+.1%}"
            # throughput regresses downward; a bytes-read series
            # regresses upward (the run started reading more)
            drifted = drift > args.threshold if unit == "bytes" else drift < -args.threshold
            if drifted:
                flagged.append((host, name, drift))
                line += "  << DRIFT"
        print(line)
        for i, (path, metric) in enumerate(points):
            rows.append((host, name, i, metric, unit, path))

    if args.csv:
        with open(args.csv, "w", encoding="utf-8") as f:
            f.write("host,entry,run_index,metric,unit,path\n")
            for host, name, i, metric, unit, path in rows:
                f.write(f"{host},{name},{i},{metric:.6g},{unit},{path}\n")
        print(f"\nwrote {len(rows)} series points to {args.csv}")

    if flagged:
        print(f"\n{len(flagged)} entr{'y' if len(flagged) == 1 else 'ies'} drifted "
              f"more than {args.threshold:.0%} below their prior median:")
        for host, name, drift in flagged:
            print(f"  {host} / {name}: {drift:+.1%}")
        return 1 if args.strict else 0
    print("\nno drift beyond threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
