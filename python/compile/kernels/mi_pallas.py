"""Layer-1 Pallas kernels for bulk mutual information.

Two kernels implement the whole hot path of the paper's optimized
algorithm (Section 3):

* ``gram``      — the single Gram matmul ``Da^T . Db`` (the O(m^2 n) term
                  that dominates everything), tiled as an (i, j, k) grid of
                  MXU-shaped blocks with an f32 VMEM accumulator.
* ``mi_combine``— the element-wise eq. (3) combine computed *only* from
                  ``(G11, colsums_a, colsums_b, n)`` — the paper's
                  N/C-derivation means no second matmul and no
                  materialized ``1 - D`` anywhere.

Hardware adaptation (DESIGN.md §6): the paper optimizes dense-matmul
throughput on a CPU; on TPU the same insight maps onto the MXU. Blocks
default to 128x128 (systolic-array shape); ``BlockSpec`` index maps
express the HBM->VMEM schedule (stream ``D`` k-tile by k-tile, keep the
output block resident across the k loop). Everything is lowered with
``interpret=True`` — the CPU PJRT plugin cannot execute Mosaic
custom-calls — so real-TPU performance is *estimated* in DESIGN.md, and
these kernels are validated for correctness against ``ref.py``.

Wrappers pad inputs up to block multiples and slice the result; padding
is exact because every derived quantity depends only on
``(G11, colsums, n)`` (zero rows add nothing) — see
``tests/test_padding.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gram", "mi_combine", "DEFAULT_BLOCK_M", "DEFAULT_BLOCK_K"]

# MXU-shaped defaults. VMEM budget per grid step at these sizes:
# 2 input tiles (128x128 f32 = 64 KiB each) + 1 f32 accumulator (64 KiB)
# ~= 192 KiB << 16 MiB VMEM. Block sizes could be raised to 256-512 on
# real silicon; kept at 128 for interpret-mode test latency.
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_K = 128


def _pad_to(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


def _gram_kernel(a_ref, b_ref, o_ref):
    """One (i, j, k) grid step: accumulate a_tile^T @ b_tile into o_tile.

    a_ref: (bk, bm) tile of Da rows; b_ref: (bk, bm) tile of Db rows;
    o_ref: (bm, bm) output block, resident in VMEM across the k loop.
    """
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    # dot_general contracting over the row (k) axis == a.T @ b; this is
    # the MXU op — bf16 inputs would feed the systolic array natively.
    o_ref[...] += jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def gram(
    Da: jnp.ndarray,
    Db: jnp.ndarray,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_k: int = DEFAULT_BLOCK_K,
) -> jnp.ndarray:
    """Cross Gram matrix ``Da^T @ Db`` via the tiled Pallas kernel.

    Da: (n, ma), Db: (n, mb) -> (ma, mb), f32.
    """
    if Da.shape[0] != Db.shape[0]:
        raise ValueError(f"row mismatch: {Da.shape} vs {Db.shape}")
    n, ma = Da.shape
    mb = Db.shape[1]
    bm = min(block_m, max(ma, 1), max(mb, 1))
    bk = min(block_k, max(n, 1))
    Da = _pad_to(_pad_to(Da.astype(jnp.float32), 0, bk), 1, bm)
    Db = _pad_to(_pad_to(Db.astype(jnp.float32), 0, bk), 1, bm)
    np_, map_ = Da.shape
    mbp = Db.shape[1]
    grid = (map_ // bm, mbp // bm, np_ // bk)
    out = pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((map_, mbp), jnp.float32),
        interpret=True,
    )(Da, Db)
    return out[:ma, :mb]


def _mi_combine_kernel(g_ref, ca_ref, cb_ref, n_ref, o_ref):
    """Element-wise eq. (3) on one (bm, bm) output block.

    Counts for cell (i, j) derive from (G11, ca, cb, n) alone — the
    Section-3 identity G00 = N - C - C^T + G11, G01 = C - G11:
    pure VPU work, fused over the Gram output tiling.
    """
    n = n_ref[0, 0]
    g = g_ref[...]
    ca = ca_ref[...].reshape(-1, 1)  # counts of ones, rows of the block
    cb = cb_ref[...].reshape(1, -1)  # counts of ones, cols of the block
    inv_n = 1.0 / n
    p11 = g * inv_n
    p10 = (ca - g) * inv_n
    p01 = (cb - g) * inv_n
    p00 = (n - ca - cb + g) * inv_n
    p1a = ca * inv_n
    p0a = 1.0 - p1a
    p1b = cb * inv_n
    p0b = 1.0 - p1b

    def term(p, e):
        safe_p = jnp.where(p > 0, p, 1.0)
        safe_e = jnp.where(e > 0, e, 1.0)
        return jnp.where(p > 0, p * (jnp.log2(safe_p) - jnp.log2(safe_e)), 0.0)

    o_ref[...] = (
        term(p11, p1a * p1b)
        + term(p10, p1a * p0b)
        + term(p01, p0a * p1b)
        + term(p00, p0a * p0b)
    )


def mi_combine(
    G11: jnp.ndarray,
    ca: jnp.ndarray,
    cb: jnp.ndarray,
    n: jnp.ndarray,
    *,
    block_m: int = DEFAULT_BLOCK_M,
) -> jnp.ndarray:
    """MI matrix (bits) from ``(G11, colsums_a, colsums_b, n)``.

    G11: (ma, mb) ones-co-occurrence counts; ca: (ma,); cb: (mb,);
    n: scalar or (1,)-shaped true row count -> (ma, mb) f32 MI.
    """
    ma, mb = G11.shape
    bm = min(block_m, max(ma, 1), max(mb, 1))
    G11 = _pad_to(_pad_to(G11.astype(jnp.float32), 0, bm), 1, bm)
    ca = _pad_to(ca.astype(jnp.float32), 0, bm)
    cb = _pad_to(cb.astype(jnp.float32), 0, bm)
    n_arr = jnp.asarray(n, dtype=jnp.float32).reshape(1, 1)
    map_, mbp = G11.shape
    grid = (map_ // bm, mbp // bm)
    out = pl.pallas_call(
        _mi_combine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bm), lambda i, j: (i, j)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (j,)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((map_, mbp), jnp.float32),
        interpret=True,
    )(G11, ca, cb, n_arr)
    return out[:ma, :mb]


@functools.partial(jax.jit, static_argnames=("block_m", "block_k"))
def bulk_mi_pallas(
    D: jnp.ndarray,
    n: jnp.ndarray,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_k: int = DEFAULT_BLOCK_K,
) -> jnp.ndarray:
    """Fused optimized bulk MI: one Pallas Gram + Pallas combine."""
    D = D.astype(jnp.float32)
    G11 = gram(D, D, block_m=block_m, block_k=block_k)
    c = jnp.sum(D, axis=0)
    return mi_combine(G11, c, c, n, block_m=block_m)
