"""Pure-jnp / numpy correctness oracles for bulk mutual information.

Two families of reference implementations:

* ``mi_pair`` / ``mi_pairwise_ref``: the textbook per-pair 2x2-contingency
  computation (numpy, no tricks).  This is what scikit-learn's
  ``mutual_info_score`` computes for binary data and is the ground truth
  every other implementation (jnp bulk forms, Pallas kernels, all five
  Rust backends) is validated against.

* ``bulk_mi_basic_ref`` / ``bulk_mi_opt_ref``: the paper's Section-2 and
  Section-3 algorithms written in plain jnp.  These serve both as oracles
  for the Pallas kernels and as the "basic vs optimized" ablation pair.

Numerical convention (shared with the Rust side, see ``mi/counts.rs``):
MI terms with a zero joint probability contribute exactly 0 —
``0 * log2(0 / e) := 0`` — implemented with masked/where arithmetic
instead of the paper's additive epsilon so the oracle is *exact*.  The
paper's epsilon variant is also provided (``bulk_mi_opt_eps_ref``) to
bound the difference between the two conventions in tests.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = [
    "mi_pair",
    "mi_pairwise_ref",
    "bulk_mi_basic_ref",
    "bulk_mi_opt_ref",
    "bulk_mi_opt_eps_ref",
    "gram_ref",
    "combine_ref",
]


def mi_pair(x: np.ndarray, y: np.ndarray) -> float:
    """Textbook MI (bits) between two binary vectors via 2x2 contingency."""
    x = np.asarray(x).astype(np.int64)
    y = np.asarray(y).astype(np.int64)
    n = x.shape[0]
    n11 = int(np.sum((x == 1) & (y == 1)))
    n10 = int(np.sum((x == 1) & (y == 0)))
    n01 = int(np.sum((x == 0) & (y == 1)))
    n00 = n - n11 - n10 - n01
    mi = 0.0
    for nxy, nx, ny in (
        (n11, n11 + n10, n11 + n01),
        (n10, n11 + n10, n10 + n00),
        (n01, n01 + n00, n11 + n01),
        (n00, n01 + n00, n10 + n00),
    ):
        if nxy > 0:
            p_xy = nxy / n
            p_x = nx / n
            p_y = ny / n
            mi += p_xy * np.log2(p_xy / (p_x * p_y))
    return float(mi)


def mi_pairwise_ref(D: np.ndarray) -> np.ndarray:
    """m x m MI matrix via the per-pair oracle (slow; small inputs only)."""
    D = np.asarray(D)
    m = D.shape[1]
    out = np.zeros((m, m), dtype=np.float64)
    for i in range(m):
        for j in range(m):
            out[i, j] = mi_pair(D[:, i], D[:, j])
    return out


def _masked_term(p: jnp.ndarray, e: jnp.ndarray) -> jnp.ndarray:
    """p * log2(p / e), with the 0*log(0) := 0 convention, NaN-safe."""
    safe_p = jnp.where(p > 0, p, 1.0)
    safe_e = jnp.where(e > 0, e, 1.0)
    return jnp.where(p > 0, p * (jnp.log2(safe_p) - jnp.log2(safe_e)), 0.0)


def bulk_mi_basic_ref(D: jnp.ndarray) -> jnp.ndarray:
    """Paper Section 2: the basic bulk algorithm with all four Gram matrices."""
    D = D.astype(jnp.float32)
    n = D.shape[0]
    nD = 1.0 - D
    G11 = D.T @ D
    G00 = nD.T @ nD
    G01 = nD.T @ D
    G10 = D.T @ nD
    P11, P00, P01, P10 = (G / n for G in (G11, G00, G01, G10))
    p1 = jnp.diag(G11) / n
    p0 = jnp.diag(G00) / n
    E11 = jnp.outer(p1, p1)
    E00 = jnp.outer(p0, p0)
    E10 = jnp.outer(p1, p0)
    E01 = jnp.outer(p0, p1)
    return (
        _masked_term(P11, E11)
        + _masked_term(P10, E10)
        + _masked_term(P01, E01)
        + _masked_term(P00, E00)
    )


def gram_ref(Da: jnp.ndarray, Db: jnp.ndarray):
    """Cross Gram + column sums: (Da^T Db, colsums(Da), colsums(Db))."""
    Da = Da.astype(jnp.float32)
    Db = Db.astype(jnp.float32)
    return Da.T @ Db, jnp.sum(Da, axis=0), jnp.sum(Db, axis=0)


def combine_ref(G11: jnp.ndarray, ca: jnp.ndarray, cb: jnp.ndarray, n) -> jnp.ndarray:
    """Paper Section 3: MI from (G11, colsums, n) alone.

    For output cell (i, j) with i indexing ``ca`` columns and j ``cb``:
      n11 = G11[i,j]          n10 = ca[i] - G11[i,j]
      n01 = cb[j] - G11[i,j]  n00 = n - ca[i] - cb[j] + G11[i,j]
    """
    n = jnp.asarray(n, dtype=jnp.float32)
    ca_col = ca[:, None]
    cb_row = cb[None, :]
    P11 = G11 / n
    P10 = (ca_col - G11) / n
    P01 = (cb_row - G11) / n
    P00 = (n - ca_col - cb_row + G11) / n
    p1a = ca_col / n
    p0a = 1.0 - p1a
    p1b = cb_row / n
    p0b = 1.0 - p1b
    return (
        _masked_term(P11, p1a * p1b)
        + _masked_term(P10, p1a * p0b)
        + _masked_term(P01, p0a * p1b)
        + _masked_term(P00, p0a * p0b)
    )


def bulk_mi_opt_ref(D: jnp.ndarray, n=None) -> jnp.ndarray:
    """Paper Section 3: optimized bulk algorithm — one Gram matmul only."""
    D = D.astype(jnp.float32)
    if n is None:
        n = D.shape[0]
    G11, c, _ = gram_ref(D, D)
    return combine_ref(G11, c, c, n)


def bulk_mi_opt_eps_ref(D: jnp.ndarray, eps: float = 1e-10) -> jnp.ndarray:
    """The paper's literal epsilon formulation (for convention-difference tests)."""
    D = D.astype(jnp.float32)
    n = D.shape[0]
    G11 = D.T @ D
    c = jnp.sum(D, axis=0)
    ca, cb = c[:, None], c[None, :]
    P11 = G11 / n
    P10 = (ca - G11) / n
    P01 = (cb - G11) / n
    P00 = (n - ca - cb + G11) / n
    p1a, p1b = ca / n, cb / n
    p0a, p0b = 1.0 - p1a, 1.0 - p1b
    out = jnp.zeros_like(G11)
    for P, E in (
        (P11, p1a * p1b),
        (P10, p1a * p0b),
        (P01, p0a * p1b),
        (P00, p0a * p0b),
    ):
        out = out + P * jnp.log2((P + eps) / (E + eps))
    return out
