"""AOT bridge: lower the Layer-2 graphs to HLO *text* artifacts.

Run once at build time (``make artifacts``); the Rust runtime
(`rust/src/runtime/artifacts.rs`) discovers the results through
``artifacts/manifest.txt`` and never touches Python again.

Interchange format is HLO TEXT, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
Everything is lowered with ``return_tuple=True`` and unwrapped with
``to_tupleN`` on the Rust side.

Manifest format (one artifact per line, '#' comments):

    name kind rows cols impl filename

* kind in {mi, gram, xgram, combine, mi_basic}
* rows is 0 for ``combine`` (row-count independent)
* impl in {xla, pallas}: same math; ``xla`` uses XLA's native dot for
  the Gram (the request-path default), ``pallas`` routes it through the
  interpret-mode Layer-1 kernel grid (correctness/ablation path).
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


# ---------------------------------------------------------------------------
# Artifact table. Shape buckets are chosen so the Rust runtime can serve
# any (n, m) by (a) padding up to the nearest bucket, or (b) row-chunking
# through `gram` + `combine` when n exceeds the largest bucket, or
# (c) column-blocking through `xgram` + `combine` when m does.
# ---------------------------------------------------------------------------

MI_BUCKETS_XLA = [(1024, 128), (2048, 256), (4096, 512), (8192, 1024), (16384, 1024)]
MI_BUCKETS_PALLAS = [(1024, 128), (2048, 256)]
GRAM_BUCKETS_XLA = [(2048, 128), (2048, 256), (2048, 512), (2048, 1024), (4096, 1024), (4096, 2048)]
GRAM_BUCKETS_PALLAS = [(1024, 128)]
XGRAM_BUCKETS_XLA = [(2048, 128), (2048, 256), (4096, 256), (4096, 512)]
XGRAM_BUCKETS_PALLAS = [(1024, 128)]
COMBINE_BUCKETS_XLA = [128, 256, 512, 1024, 2048]
COMBINE_BUCKETS_PALLAS = [128, 256]
MI_BASIC_BUCKETS = [(1024, 128), (2048, 256)]


def artifact_table():
    """Yield (name, kind, rows, cols, impl, fn, arg_specs) tuples."""
    for r, c in MI_BUCKETS_XLA:
        yield (f"mi_xla_{r}x{c}", "mi", r, c, "xla", model.mi_fused_xla, (_spec(r, c), _spec(1)))
    for r, c in MI_BUCKETS_PALLAS:
        yield (f"mi_pallas_{r}x{c}", "mi", r, c, "pallas", model.mi_fused, (_spec(r, c), _spec(1)))
    for r, c in GRAM_BUCKETS_XLA:
        yield (f"gram_xla_{r}x{c}", "gram", r, c, "xla", model.gram_partial_xla, (_spec(r, c),))
    for r, c in GRAM_BUCKETS_PALLAS:
        yield (f"gram_pallas_{r}x{c}", "gram", r, c, "pallas", model.gram_partial, (_spec(r, c),))
    for r, c in XGRAM_BUCKETS_XLA:
        yield (
            f"xgram_xla_{r}x{c}", "xgram", r, c, "xla",
            model.xgram_partial_xla, (_spec(r, c), _spec(r, c)),
        )
    for r, c in XGRAM_BUCKETS_PALLAS:
        yield (
            f"xgram_pallas_{r}x{c}", "xgram", r, c, "pallas",
            model.xgram_partial, (_spec(r, c), _spec(r, c)),
        )
    for c in COMBINE_BUCKETS_XLA:
        yield (
            f"combine_xla_{c}", "combine", 0, c, "xla",
            model.combine_xla, (_spec(c, c), _spec(c), _spec(c), _spec(1)),
        )
    for c in COMBINE_BUCKETS_PALLAS:
        yield (
            f"combine_pallas_{c}", "combine", 0, c, "pallas",
            model.combine, (_spec(c, c), _spec(c), _spec(c), _spec(1)),
        )
    for r, c in MI_BASIC_BUCKETS:
        yield (f"mi_basic_{r}x{c}", "mi_basic", r, c, "xla", model.mi_basic, (_spec(r, c),))


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(fn, arg_specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*arg_specs))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    ap.add_argument(
        "--force", action="store_true",
        help="re-lower even if the artifact file already exists",
    )
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_lines = ["# name kind rows cols impl filename"]
    n_written = n_skipped = 0
    for name, kind, rows, cols, impl, fn, specs in artifact_table():
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        manifest_lines.append(f"{name} {kind} {rows} {cols} {impl} {fname}")
        if args.only and args.only not in name:
            continue
        if os.path.exists(path) and not args.force:
            n_skipped += 1
            continue
        text = lower_one(fn, specs)
        with open(path, "w") as f:
            f.write(text)
        n_written += 1
        print(f"  lowered {name:<24} {len(text):>10} chars", file=sys.stderr)

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"aot: {n_written} lowered, {n_skipped} up-to-date -> {args.out_dir}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
