"""Layer-2 JAX compute graphs for bulk mutual information.

These are the functions that get AOT-lowered (``aot.py``) into the HLO
artifacts the Rust runtime executes. Each is a thin composition over the
Layer-1 Pallas kernels (``kernels.mi_pallas``); nothing here runs at
request time — Python exists only on the compile path.

Entry points (all return tuples — the AOT bridge lowers with
``return_tuple=True`` and Rust unwraps with ``to_tupleN``):

* ``mi_fused(D, n1)``      — full optimized bulk MI in one executable.
* ``gram_partial(D)``      — (G11 partial, colsums partial) for one row
                             chunk; Rust sums chunk outputs (exact).
* ``xgram_partial(Da,Db)`` — cross-block Gram for column blocking.
* ``combine(G11,ca,cb,n1)``— MI from accumulated counts.
* ``mi_basic(D)``          — the *un*-optimized Section-2 algorithm
                             (4 Gram matmuls), kept for the ablation
                             bench; deliberately NOT Pallas-tiled.

``n1`` is the true (un-padded) row count as an ``f32[1]`` — scalar
plumbing through the text-HLO bridge is simpler with a rank-1 literal.
Padding exactness: see DESIGN.md §2 and tests/test_padding.py.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import mi_pallas
from .kernels.ref import bulk_mi_basic_ref, combine_ref, gram_ref

__all__ = [
    "mi_fused",
    "gram_partial",
    "xgram_partial",
    "combine",
    "mi_basic",
    "mi_fused_xla",
    "gram_partial_xla",
    "xgram_partial_xla",
    "combine_xla",
]


def mi_fused(D: jnp.ndarray, n1: jnp.ndarray):
    """Optimized bulk MI (paper §3) for a whole (padded) dataset.

    D: f32[R, C] zero-padded binary data; n1: f32[1] true row count.
    Returns (f32[C, C] MI matrix in bits,).
    """
    D = D.astype(jnp.float32)
    n = n1[0]
    G11 = mi_pallas.gram(D, D)
    c = jnp.sum(D, axis=0)
    return (mi_pallas.mi_combine(G11, c, c, n),)


def gram_partial(D: jnp.ndarray):
    """Partial Gram + colsums for one row chunk (exact under summation)."""
    D = D.astype(jnp.float32)
    return (mi_pallas.gram(D, D), jnp.sum(D, axis=0))


def xgram_partial(Da: jnp.ndarray, Db: jnp.ndarray):
    """Cross-block partial Gram + both colsums, for column-block pairs."""
    Da = Da.astype(jnp.float32)
    Db = Db.astype(jnp.float32)
    return (
        mi_pallas.gram(Da, Db),
        jnp.sum(Da, axis=0),
        jnp.sum(Db, axis=0),
    )


def combine(G11: jnp.ndarray, ca: jnp.ndarray, cb: jnp.ndarray, n1: jnp.ndarray):
    """MI from accumulated (G11, colsums, n) counts."""
    return (mi_pallas.mi_combine(G11, ca, cb, n1[0]),)


def mi_basic(D: jnp.ndarray):
    """Paper §2 basic algorithm (4 Gram matmuls) — ablation comparator."""
    return (bulk_mi_basic_ref(D),)


# ---------------------------------------------------------------------------
# "xla" implementation variants: identical math, but the Gram runs on
# XLA's native `dot` instead of the interpret-mode Pallas grid loop.
# Interpret mode emulates the TPU grid as a sequential HLO while-loop,
# which is the right *structure* for the MXU but slow on the CPU PJRT
# backend; these variants are what the Rust runtime executes on the
# Table-1 hot path (the paper's "Opt-T" optimized-framework row), while
# the Pallas variants prove the L1 kernels lower and run end-to-end.
# ---------------------------------------------------------------------------


def mi_fused_xla(D: jnp.ndarray, n1: jnp.ndarray):
    """Optimized bulk MI with an XLA-native Gram dot."""
    D = D.astype(jnp.float32)
    G11, c, _ = gram_ref(D, D)
    return (combine_ref(G11, c, c, n1[0]),)


def gram_partial_xla(D: jnp.ndarray):
    """Partial Gram + colsums via XLA-native dot."""
    G11, c, _ = gram_ref(D, D)
    return (G11, c)


def xgram_partial_xla(Da: jnp.ndarray, Db: jnp.ndarray):
    """Cross-block partial Gram via XLA-native dot."""
    return gram_ref(Da, Db)


def combine_xla(G11: jnp.ndarray, ca: jnp.ndarray, cb: jnp.ndarray, n1: jnp.ndarray):
    """MI combine via plain jnp ops."""
    return (combine_ref(G11, ca, cb, n1[0]),)
