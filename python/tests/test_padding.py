"""Padding/chunking exactness — the property the Rust runtime's shape
bucketing relies on (DESIGN.md §2): every quantity in the optimized
algorithm is a function of (G11, colsums, n) only, so

* zero-padding ROWS is exact when the true n is passed as a scalar;
* zero-padding COLUMNS only pollutes output rows/cols that get sliced away;
* row-chunked accumulation of (G11, colsums) is exact.
"""

from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels.ref import bulk_mi_opt_ref
from conftest import random_binary


def pad_rows(D, total):
    out = np.zeros((total, D.shape[1]), dtype=D.dtype)
    out[: D.shape[0]] = D
    return out


def pad_cols(D, total):
    out = np.zeros((D.shape[0], total), dtype=D.dtype)
    out[:, : D.shape[1]] = D
    return out


class TestRowPadding:
    def test_row_padding_exact(self):
        rng = np.random.default_rng(1)
        D = random_binary(rng, 77, 10, 0.8)
        want = np.asarray(bulk_mi_opt_ref(D))
        padded = pad_rows(D, 128)
        (got,) = model.mi_fused_xla(padded, np.array([77.0], np.float32))
        assert_allclose(np.asarray(got), want, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(2, 100), extra=st.integers(0, 100), m=st.integers(1, 20))
    def test_row_padding_hypothesis(self, n, extra, m):
        rng = np.random.default_rng(n * 7 + extra + m)
        D = random_binary(rng, n, m, 0.7)
        want = np.asarray(bulk_mi_opt_ref(D))
        (got,) = model.mi_fused_xla(pad_rows(D, n + extra), np.array([float(n)], np.float32))
        assert_allclose(np.asarray(got), want, atol=1e-5)


class TestColPadding:
    def test_col_padding_slices_clean(self):
        rng = np.random.default_rng(2)
        D = random_binary(rng, 64, 9, 0.8)
        want = np.asarray(bulk_mi_opt_ref(D))
        (got,) = model.mi_fused_xla(pad_cols(D, 16), np.array([64.0], np.float32))
        got = np.asarray(got)
        assert not np.any(np.isnan(got))  # padded cells must stay finite
        assert_allclose(got[:9, :9], want, atol=1e-5)

    def test_row_and_col_padding_together(self):
        rng = np.random.default_rng(3)
        D = random_binary(rng, 50, 6, 0.6)
        want = np.asarray(bulk_mi_opt_ref(D))
        padded = pad_cols(pad_rows(D, 128), 16)
        (got,) = model.mi_fused_xla(padded, np.array([50.0], np.float32))
        assert_allclose(np.asarray(got)[:6, :6], want, atol=1e-5)


class TestChunkedAccumulation:
    def test_gram_partials_sum_to_full(self):
        rng = np.random.default_rng(4)
        D = random_binary(rng, 150, 12, 0.85)
        G = np.zeros((12, 12), np.float64)
        c = np.zeros(12, np.float64)
        for lo, hi in [(0, 64), (64, 128), (128, 150)]:
            chunk = pad_rows(D[lo:hi], 64)  # Rust pads the tail chunk too
            Gp, cp = model.gram_partial_xla(chunk)
            G += np.asarray(Gp)
            c += np.asarray(cp)
        (got,) = model.combine_xla(
            G.astype(np.float32), c.astype(np.float32), c.astype(np.float32),
            np.array([150.0], np.float32),
        )
        assert_allclose(np.asarray(got), np.asarray(bulk_mi_opt_ref(D)), atol=1e-5)

    def test_xgram_block_pair_matches_full(self):
        rng = np.random.default_rng(5)
        D = random_binary(rng, 90, 20, 0.75)
        full = np.asarray(bulk_mi_opt_ref(D))
        Da, Db = D[:, :8], D[:, 8:]
        G, ca, cb = model.xgram_partial_xla(pad_cols(Da, 8), pad_cols(Db, 12))
        (got,) = model.combine_xla(
            np.asarray(G), np.asarray(ca), np.asarray(cb), np.array([90.0], np.float32)
        )
        assert_allclose(np.asarray(got), full[:8, 8:], atol=1e-5)

    def test_pallas_gram_partials_match_xla(self):
        rng = np.random.default_rng(6)
        D = random_binary(rng, 128, 16, 0.9)
        Gx, cx = model.gram_partial_xla(D)
        Gp, cp = model.gram_partial(D)
        assert_allclose(np.asarray(Gp), np.asarray(Gx), atol=0)
        assert_allclose(np.asarray(cp), np.asarray(cx), atol=0)
