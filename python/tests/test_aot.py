"""AOT bridge sanity: artifacts lower to valid HLO text with the expected
structure, and the manifest covers the full table.

The perf-critical structural assertion: the optimized ("opt"/Section-3)
lowerings must contain exactly ONE large dot — the Gram — with the other
three Gram matrices derived arithmetically. The basic (Section-2)
lowering must contain the paper's four.
"""

from __future__ import annotations

import re

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def count_dots(hlo: str) -> int:
    # Count dot ops over rank-2 operands (matrix products), ignoring any
    # rank-1 reductions XLA might express as dots.
    return len(re.findall(r"= f32\[\d+,\d+\]\{[0-9,]*\} dot\(", hlo))


class TestLowering:
    def test_mi_xla_lowers_with_single_dot(self):
        hlo = aot.lower_one(model.mi_fused_xla, (aot._spec(256, 32), aot._spec(1)))
        assert "HloModule" in hlo
        assert count_dots(hlo) == 1, f"optimized path must have 1 Gram dot, got {count_dots(hlo)}"

    def test_mi_basic_lowers_with_four_dots(self):
        hlo = aot.lower_one(model.mi_basic, (aot._spec(256, 32),))
        assert count_dots(hlo) == 4

    def test_gram_partial_single_dot(self):
        hlo = aot.lower_one(model.gram_partial_xla, (aot._spec(128, 16),))
        assert count_dots(hlo) == 1

    def test_combine_has_no_dot(self):
        hlo = aot.lower_one(
            model.combine_xla,
            (aot._spec(32, 32), aot._spec(32), aot._spec(32), aot._spec(1)),
        )
        assert count_dots(hlo) == 0

    def test_pallas_variant_lowers(self):
        # interpret-mode pallas must lower to plain HLO (no custom-calls
        # the CPU PJRT client can't run).
        hlo = aot.lower_one(model.mi_fused, (aot._spec(256, 128), aot._spec(1)))
        assert "HloModule" in hlo
        assert "custom-call" not in hlo.lower() or "mosaic" not in hlo.lower()


class TestArtifactTable:
    def test_table_is_well_formed(self):
        names = set()
        for name, kind, rows, cols, impl, fn, specs in aot.artifact_table():
            assert name not in names, f"duplicate artifact {name}"
            names.add(name)
            assert kind in ("mi", "gram", "xgram", "combine", "mi_basic")
            assert impl in ("xla", "pallas")
            assert cols > 0
            assert (rows == 0) == (kind == "combine")
            assert callable(fn)

    def test_table_covers_required_kinds(self):
        kinds = {k for _, k, *_ in aot.artifact_table()}
        assert kinds == {"mi", "gram", "xgram", "combine", "mi_basic"}

    def test_every_mi_bucket_has_combine_for_its_cols(self):
        # The row-chunking path needs a combine artifact for every gram
        # bucket's column count.
        combine_cols = {c for _, k, _, c, i, *_ in aot.artifact_table() if k == "combine" and i == "xla"}
        gram_cols = {c for _, k, _, c, i, *_ in aot.artifact_table() if k == "gram" and i == "xla"}
        assert gram_cols <= combine_cols | gram_cols  # trivially true...
        missing = {c for c in gram_cols if c not in combine_cols}
        assert not missing, f"gram buckets without combine artifact: {missing}"


class TestLoweredNumerics:
    def test_lowered_fused_executes_correctly(self):
        # Round-trip within python: the jitted function (what gets
        # lowered) must equal the oracle on a bucket-shaped input.
        from compile.kernels.ref import bulk_mi_opt_ref

        rng = np.random.default_rng(0)
        D = (rng.random((128, 16)) > 0.9).astype(np.float32)
        padded = np.zeros((256, 32), np.float32)
        padded[:128, :16] = D
        (out,) = model.mi_fused_xla(jnp.asarray(padded), jnp.array([128.0]))
        want = np.asarray(bulk_mi_opt_ref(D))
        np.testing.assert_allclose(np.asarray(out)[:16, :16], want, atol=1e-5)
