"""Shared fixtures/helpers for the python-side test suite."""

from __future__ import annotations

import os
import sys

import numpy as np

# Make `compile` importable when pytest is run from python/ or repo root.
_HERE = os.path.dirname(os.path.abspath(__file__))
_PYROOT = os.path.dirname(_HERE)
for _p in (_PYROOT, _HERE):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def random_binary(rng: np.random.Generator, n: int, m: int, sparsity: float = 0.9):
    """n x m binary matrix with P(zero) = sparsity."""
    return (rng.random((n, m)) >= sparsity).astype(np.float32)
