"""Layer-2 model graphs: parity between the Pallas and XLA-native
variants, composition of the chunk/block entry points, and the tuple
output contract the Rust runtime relies on."""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels.ref import bulk_mi_basic_ref, bulk_mi_opt_ref
from conftest import random_binary


class TestVariantParity:
    @pytest.mark.parametrize("n,m", [(64, 16), (128, 128), (200, 40)])
    def test_pallas_and_xla_fused_agree(self, n, m):
        rng = np.random.default_rng(n + m)
        D = random_binary(rng, n, m, 0.85)
        n1 = np.array([float(n)], np.float32)
        (a,) = model.mi_fused(D, n1)
        (b,) = model.mi_fused_xla(D, n1)
        assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

    def test_fused_matches_oracle(self):
        rng = np.random.default_rng(5)
        D = random_binary(rng, 150, 20, 0.9)
        (out,) = model.mi_fused_xla(D, np.array([150.0], np.float32))
        assert_allclose(np.asarray(out), np.asarray(bulk_mi_opt_ref(D)), atol=1e-5)

    def test_basic_matches_section2_oracle(self):
        rng = np.random.default_rng(6)
        D = random_binary(rng, 100, 12, 0.7)
        (out,) = model.mi_basic(D)
        assert_allclose(np.asarray(out), np.asarray(bulk_mi_basic_ref(D)), atol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(4, 120), m=st.integers(2, 24), s=st.floats(0.3, 0.98))
    def test_variant_parity_hypothesis(self, n, m, s):
        rng = np.random.default_rng(n * 131 + m)
        D = random_binary(rng, n, m, s)
        n1 = np.array([float(n)], np.float32)
        (a,) = model.mi_fused(D, n1)
        (b,) = model.mi_fused_xla(D, n1)
        assert not np.any(np.isnan(np.asarray(a)))
        assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


class TestComposition:
    def test_gram_then_combine_equals_fused(self):
        rng = np.random.default_rng(7)
        D = random_binary(rng, 90, 14, 0.8)
        G, c = model.gram_partial_xla(D)
        (via_parts,) = model.combine_xla(G, c, c, np.array([90.0], np.float32))
        (fused,) = model.mi_fused_xla(D, np.array([90.0], np.float32))
        assert_allclose(np.asarray(via_parts), np.asarray(fused), atol=1e-6)

    def test_xgram_tiles_assemble_full_matrix(self):
        rng = np.random.default_rng(8)
        D = random_binary(rng, 70, 12, 0.75)
        n1 = np.array([70.0], np.float32)
        full = np.asarray(model.mi_fused_xla(D, n1)[0])
        blocks = [(0, 6), (6, 6)]
        out = np.zeros((12, 12), np.float32)
        for a_start, a_len in blocks:
            for b_start, b_len in blocks:
                Da = D[:, a_start : a_start + a_len]
                Db = D[:, b_start : b_start + b_len]
                G, ca, cb = model.xgram_partial_xla(Da, Db)
                (mi,) = model.combine_xla(np.asarray(G), np.asarray(ca), np.asarray(cb), n1)
                out[a_start : a_start + a_len, b_start : b_start + b_len] = np.asarray(mi)
        assert_allclose(out, full, atol=1e-5)

    def test_outputs_are_tuples(self):
        # the AOT bridge lowers with return_tuple=True; rust unwraps
        # to_tupleN — every entry point must return a tuple.
        rng = np.random.default_rng(9)
        D = random_binary(rng, 32, 8, 0.5)
        n1 = np.array([32.0], np.float32)
        assert isinstance(model.mi_fused(D, n1), tuple)
        assert isinstance(model.mi_fused_xla(D, n1), tuple)
        assert isinstance(model.gram_partial(D), tuple)
        assert isinstance(model.gram_partial_xla(D), tuple)
        assert isinstance(model.xgram_partial(D, D), tuple)
        assert isinstance(model.xgram_partial_xla(D, D), tuple)
        assert isinstance(model.mi_basic(D), tuple)
        G, c = model.gram_partial_xla(D)
        assert isinstance(model.combine(G, c, c, n1), tuple)
        assert isinstance(model.combine_xla(G, c, c, n1), tuple)
