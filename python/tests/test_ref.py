"""Validate the oracles themselves: bulk forms vs the textbook per-pair MI,
plus closed-form identities. If these fail nothing downstream is trustworthy.
"""

from __future__ import annotations

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels.ref import (
    bulk_mi_basic_ref,
    bulk_mi_opt_ref,
    bulk_mi_opt_eps_ref,
    combine_ref,
    gram_ref,
    mi_pair,
    mi_pairwise_ref,
)
from conftest import random_binary


def entropy_bits(p: float) -> float:
    if p <= 0.0 or p >= 1.0:
        return 0.0
    return float(-p * np.log2(p) - (1 - p) * np.log2(1 - p))


class TestMiPair:
    def test_identical_columns_give_entropy(self):
        x = np.array([1, 1, 0, 0, 1, 0, 1, 1])
        p = x.mean()
        assert_allclose(mi_pair(x, x), entropy_bits(p), rtol=1e-12)

    def test_complementary_columns_give_entropy(self):
        x = np.array([1, 0, 0, 1, 1, 0])
        assert_allclose(mi_pair(x, 1 - x), entropy_bits(x.mean()), rtol=1e-12)

    def test_constant_column_gives_zero(self):
        x = np.zeros(10, dtype=int)
        y = np.array([0, 1] * 5)
        assert mi_pair(x, y) == 0.0
        assert mi_pair(y, x) == 0.0
        assert mi_pair(x, x) == 0.0

    def test_perfectly_balanced_independent(self):
        # x/y hit every 2x2 cell equally -> exactly independent -> MI = 0.
        x = np.array([0, 0, 1, 1])
        y = np.array([0, 1, 0, 1])
        assert_allclose(mi_pair(x, y), 0.0, atol=1e-12)

    def test_symmetry(self):
        rng = np.random.default_rng(0)
        for _ in range(20):
            x = (rng.random(50) > 0.6).astype(int)
            y = (rng.random(50) > 0.3).astype(int)
            assert_allclose(mi_pair(x, y), mi_pair(y, x), rtol=1e-12)

    def test_nonnegative_and_bounded(self):
        rng = np.random.default_rng(1)
        for _ in range(50):
            x = (rng.random(64) > rng.random()).astype(int)
            y = (rng.random(64) > rng.random()).astype(int)
            mi = mi_pair(x, y)
            assert mi >= -1e-12
            assert mi <= min(entropy_bits(x.mean()), entropy_bits(y.mean())) + 1e-9


class TestBulkForms:
    @pytest.mark.parametrize("n,m,sparsity", [(40, 7, 0.5), (100, 13, 0.9), (64, 16, 0.2)])
    def test_basic_matches_pairwise(self, n, m, sparsity):
        rng = np.random.default_rng(n * m)
        D = random_binary(rng, n, m, sparsity)
        assert_allclose(np.asarray(bulk_mi_basic_ref(D)), mi_pairwise_ref(D), atol=2e-5)

    @pytest.mark.parametrize("n,m,sparsity", [(40, 7, 0.5), (100, 13, 0.9), (64, 16, 0.2)])
    def test_opt_matches_pairwise(self, n, m, sparsity):
        rng = np.random.default_rng(n * m + 1)
        D = random_binary(rng, n, m, sparsity)
        assert_allclose(np.asarray(bulk_mi_opt_ref(D)), mi_pairwise_ref(D), atol=2e-5)

    def test_opt_matches_basic_exactly_in_float(self):
        rng = np.random.default_rng(7)
        D = random_binary(rng, 128, 32, 0.8)
        assert_allclose(
            np.asarray(bulk_mi_opt_ref(D)), np.asarray(bulk_mi_basic_ref(D)), atol=1e-5
        )

    def test_eps_variant_close_to_masked(self):
        # The paper's +eps formulation differs from the exact masked form
        # by O(eps * n-cells); confirm it is numerically negligible.
        rng = np.random.default_rng(11)
        D = random_binary(rng, 200, 20, 0.9)
        assert_allclose(
            np.asarray(bulk_mi_opt_eps_ref(D)), np.asarray(bulk_mi_opt_ref(D)), atol=1e-4
        )

    def test_constant_columns_all_zero_mi(self):
        D = np.zeros((30, 5), dtype=np.float32)
        D[:, 2] = 1.0  # constant-one column
        out = np.asarray(bulk_mi_opt_ref(D))
        assert_allclose(out, 0.0, atol=1e-7)

    def test_diag_equals_entropy(self):
        rng = np.random.default_rng(3)
        D = random_binary(rng, 256, 10, 0.7)
        out = np.asarray(bulk_mi_opt_ref(D))
        for j in range(10):
            assert_allclose(out[j, j], entropy_bits(D[:, j].mean()), atol=1e-5)


class TestGramCombine:
    def test_gram_counts(self):
        rng = np.random.default_rng(5)
        D = random_binary(rng, 50, 8, 0.6)
        G, ca, cb = (np.asarray(x) for x in gram_ref(D, D))
        assert_allclose(G, D.T @ D, atol=0)
        assert_allclose(ca, D.sum(axis=0), atol=0)
        assert_allclose(cb, D.sum(axis=0), atol=0)

    def test_cross_gram_rectangular(self):
        rng = np.random.default_rng(6)
        Da = random_binary(rng, 50, 5, 0.6)
        Db = random_binary(rng, 50, 9, 0.4)
        G, ca, cb = (np.asarray(x) for x in gram_ref(Da, Db))
        assert G.shape == (5, 9)
        assert_allclose(G, Da.T @ Db, atol=0)

    def test_combine_equals_pairwise_on_blocks(self):
        rng = np.random.default_rng(8)
        D = random_binary(rng, 80, 12, 0.7)
        Da, Db = D[:, :5], D[:, 5:]
        G, ca, cb = gram_ref(Da, Db)
        out = np.asarray(combine_ref(G, ca, cb, 80))
        full = mi_pairwise_ref(D)
        assert_allclose(out, full[:5, 5:], atol=2e-5)

    def test_combine_row_chunk_accumulation_is_exact(self):
        # G11 and colsums are sums over rows: chunked accumulation must
        # reproduce the monolithic result exactly (this is what the Rust
        # coordinator relies on for n > bucket rows).
        rng = np.random.default_rng(9)
        D = random_binary(rng, 120, 10, 0.8)
        chunks = [D[:50], D[50:90], D[90:]]
        G = np.zeros((10, 10), dtype=np.float64)
        c = np.zeros(10, dtype=np.float64)
        for ch in chunks:
            Gp, cp, _ = (np.asarray(x) for x in gram_ref(ch, ch))
            G += Gp
            c += cp
        out = np.asarray(combine_ref(G.astype(np.float32), c.astype(np.float32), c.astype(np.float32), 120))
        assert_allclose(out, np.asarray(bulk_mi_opt_ref(D)), atol=1e-5)
