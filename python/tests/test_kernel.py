"""Layer-1 Pallas kernels vs the pure-jnp oracle — the CORE correctness
signal for the compiled hot path. Includes hypothesis sweeps over shapes,
sparsity and block sizes (uneven tails exercised via the pad-and-slice
wrappers)."""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import mi_pallas
from compile.kernels.ref import bulk_mi_opt_ref, combine_ref, gram_ref, mi_pairwise_ref
from conftest import random_binary


class TestGramKernel:
    @pytest.mark.parametrize("n,m", [(8, 8), (64, 16), (100, 13), (129, 7), (256, 128)])
    def test_gram_matches_matmul(self, n, m):
        rng = np.random.default_rng(n + m)
        D = random_binary(rng, n, m, 0.7)
        got = np.asarray(mi_pallas.gram(D, D, block_m=16, block_k=32))
        assert_allclose(got, D.T @ D, atol=0)

    def test_gram_cross_rectangular(self):
        rng = np.random.default_rng(2)
        Da = random_binary(rng, 70, 11, 0.5)
        Db = random_binary(rng, 70, 19, 0.8)
        got = np.asarray(mi_pallas.gram(Da, Db, block_m=8, block_k=16))
        assert_allclose(got, Da.T @ Db, atol=0)

    def test_gram_counts_are_integers(self):
        rng = np.random.default_rng(3)
        D = random_binary(rng, 200, 24, 0.9)
        got = np.asarray(mi_pallas.gram(D, D))
        assert_allclose(got, np.round(got), atol=0)

    def test_gram_row_mismatch_raises(self):
        with pytest.raises(ValueError):
            mi_pallas.gram(np.zeros((4, 3), np.float32), np.zeros((5, 3), np.float32))

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(1, 200),
        m=st.integers(1, 40),
        sparsity=st.floats(0.0, 1.0),
        bm=st.sampled_from([4, 8, 16, 128]),
        bk=st.sampled_from([8, 32, 128]),
    )
    def test_gram_hypothesis(self, n, m, sparsity, bm, bk):
        rng = np.random.default_rng(n * 1000 + m)
        D = random_binary(rng, n, m, sparsity)
        got = np.asarray(mi_pallas.gram(D, D, block_m=bm, block_k=bk))
        assert_allclose(got, D.T @ D, atol=0)


class TestCombineKernel:
    @pytest.mark.parametrize("m", [4, 13, 128, 130])
    def test_combine_matches_ref(self, m):
        rng = np.random.default_rng(m)
        D = random_binary(rng, 90, m, 0.8)
        G, c, _ = (np.asarray(x) for x in gram_ref(D, D))
        got = np.asarray(mi_pallas.mi_combine(G, c, c, 90.0, block_m=16))
        want = np.asarray(combine_ref(G, c, c, 90))
        assert_allclose(got, want, atol=1e-6)

    def test_combine_rectangular_blocks(self):
        rng = np.random.default_rng(21)
        D = random_binary(rng, 64, 20, 0.6)
        Da, Db = D[:, :8], D[:, 8:]
        G, ca, cb = (np.asarray(x) for x in gram_ref(Da, Db))
        got = np.asarray(mi_pallas.mi_combine(G, ca, cb, 64.0, block_m=8))
        assert_allclose(got, mi_pairwise_ref(D)[:8, 8:], atol=2e-5)

    def test_combine_zero_and_constant_columns(self):
        # all-zero and all-one columns must produce exactly 0 MI, no NaNs.
        D = np.zeros((40, 6), dtype=np.float32)
        D[:, 1] = 1.0
        D[::2, 3] = 1.0
        G, c, _ = (np.asarray(x) for x in gram_ref(D, D))
        got = np.asarray(mi_pallas.mi_combine(G, c, c, 40.0, block_m=8))
        assert not np.any(np.isnan(got))
        assert got[0, 0] == 0.0 and got[1, 1] == 0.0
        assert_allclose(got[3, 3], 1.0, atol=1e-6)  # balanced col -> H = 1 bit

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(2, 150), m=st.integers(1, 30), sparsity=st.floats(0.1, 0.99))
    def test_combine_hypothesis(self, n, m, sparsity):
        rng = np.random.default_rng(n * 31 + m)
        D = random_binary(rng, n, m, sparsity)
        G, c, _ = (np.asarray(x) for x in gram_ref(D, D))
        got = np.asarray(mi_pallas.mi_combine(G, c, c, float(n), block_m=8))
        want = np.asarray(combine_ref(G, c, c, n))
        assert not np.any(np.isnan(got))
        assert_allclose(got, want, atol=1e-5)


class TestFusedPallas:
    @pytest.mark.parametrize("n,m", [(64, 8), (100, 13), (256, 32)])
    def test_fused_matches_pairwise(self, n, m):
        rng = np.random.default_rng(n ^ m)
        D = random_binary(rng, n, m, 0.85)
        got = np.asarray(mi_pallas.bulk_mi_pallas(D, float(n), block_m=16, block_k=32))
        assert_allclose(got, mi_pairwise_ref(D), atol=2e-5)

    def test_fused_matches_opt_ref(self):
        rng = np.random.default_rng(99)
        D = random_binary(rng, 300, 40, 0.9)
        got = np.asarray(mi_pallas.bulk_mi_pallas(D, 300.0))
        assert_allclose(got, np.asarray(bulk_mi_opt_ref(D)), atol=1e-5)

    def test_fused_symmetric_nonnegative(self):
        rng = np.random.default_rng(123)
        D = random_binary(rng, 128, 24, 0.5)
        got = np.asarray(mi_pallas.bulk_mi_pallas(D, 128.0, block_m=8, block_k=16))
        assert_allclose(got, got.T, atol=1e-5)
        assert np.all(got >= -1e-6)
