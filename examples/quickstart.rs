//! Quickstart: generate a synthetic binary dataset with two planted
//! dependencies, compute the full MI matrix, and read off the strongest
//! pairs.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use bulkmi::data::synth::SynthSpec;
use bulkmi::mi::backend::{compute_mi, Backend};
use bulkmi::mi::entropy::{normalized_mi, Normalization};
use bulkmi::mi::topk::top_k_pairs;
use bulkmi::util::timer::{fmt_secs, time_it};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 10k samples x 200 binary variables, 90% sparse, with two planted
    // dependent pairs the analysis should find.
    let ds = SynthSpec::new(10_000, 200)
        .sparsity(0.9)
        .seed(7)
        .plant(3, 17, 0.02) // strong dependency
        .plant(50, 51, 0.15) // weaker dependency
        .generate();
    println!(
        "dataset: {} rows x {} cols, sparsity {:.3}",
        ds.n_rows(),
        ds.n_cols(),
        ds.sparsity()
    );

    // One call computes all 200x200 pairwise MIs (the paper's bulk
    // algorithm on the bit-packed popcount substrate).
    let (mi, secs) = time_it(|| compute_mi(&ds, Backend::BulkBitpack));
    let mi = mi?;
    println!("bulk MI over {} pairs in {}", 200 * 199 / 2, fmt_secs(secs));

    println!("\nstrongest pairs (bits):");
    for p in top_k_pairs(&mi, 5) {
        println!("  ({:>3}, {:>3})  MI = {:.4}", p.i, p.j, p.mi);
    }

    // Normalized view: 1.0 means one variable determines the other.
    let nmi = normalized_mi(&ds, &mi, Normalization::Min);
    println!("\nnormalized (min-entropy) for the planted pairs:");
    println!("  (3, 17):  {:.4}", nmi.get(3, 17));
    println!("  (50, 51): {:.4}", nmi.get(50, 51));

    // the planted pairs must be the top two
    let top = top_k_pairs(&mi, 2);
    assert_eq!((top[0].i, top[0].j), (3, 17), "strongest pair should be the planted copy");
    assert_eq!((top[1].i, top[1].j), (50, 51));
    println!("\nquickstart OK");
    Ok(())
}
