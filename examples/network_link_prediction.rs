//! Network-science scenario (paper §1: "binary adjacency matrices
//! represent connections between nodes"): MI between adjacency columns
//! measures neighborhood overlap. On a planted-partition graph, high-MI
//! node pairs should be same-community — the MI-based link/community
//! signal of Tan et al. (paper ref [16]).
//!
//! ```sh
//! cargo run --release --example network_link_prediction
//! ```

// The numeric checks deliberately index by (row, col) to mirror the
// paper's pseudocode (same rationale as the crate-level allow in lib.rs).
#![allow(clippy::needless_range_loop)]

use bulkmi::data::graph::SbmSpec;
use bulkmi::mi::backend::{compute_mi, Backend};
use bulkmi::mi::topk::top_k_pairs;
use bulkmi::util::timer::{fmt_secs, time_it};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let spec = SbmSpec { n_nodes: 240, k: 4, p_in: 0.35, p_out: 0.03, seed: 21 };
    let graph = spec.generate();
    let adj = &graph.adjacency;
    println!(
        "graph: {} nodes, {} communities, adjacency sparsity {:.3}",
        spec.n_nodes,
        spec.k,
        adj.sparsity()
    );

    let (mi, secs) = time_it(|| compute_mi(adj, Backend::BulkBitpack));
    let mi = mi?;
    println!(
        "bulk MI over {} node pairs in {}",
        spec.n_nodes * (spec.n_nodes - 1) / 2,
        fmt_secs(secs)
    );

    // top pairs should be same-community (shared neighborhoods)
    let k_eval = 200;
    let top = top_k_pairs(&mi, k_eval);
    let same = top
        .iter()
        .filter(|p| graph.community[p.i] == graph.community[p.j])
        .count();
    let precision = same as f64 / k_eval as f64;
    println!("top-{k_eval} MI pairs: {same} same-community (precision {precision:.3})");

    // simple community recovery: assign each node to its highest-MI peer's
    // community and measure agreement
    let mut correct = 0usize;
    for i in 0..spec.n_nodes {
        let mut best = (0usize, f64::NEG_INFINITY);
        for j in 0..spec.n_nodes {
            if j != i && mi.get(i, j) > best.1 {
                best = (j, mi.get(i, j));
            }
        }
        if graph.community[best.0] == graph.community[i] {
            correct += 1;
        }
    }
    let acc = correct as f64 / spec.n_nodes as f64;
    println!("nearest-MI-neighbor community agreement: {acc:.3}");

    assert!(precision > 0.9, "same-community precision {precision} too low");
    assert!(acc > 0.9, "neighbor agreement {acc} too low");
    println!("\nnetwork link prediction OK");
    Ok(())
}
