//! NLP scenario (paper §1): word-association mining over a bag-of-words
//! presence matrix. MI between vocabulary columns surfaces topical word
//! pairs from the built-in mini-corpus.
//!
//! ```sh
//! cargo run --release --example text_associations
//! ```

use bulkmi::data::text::{binarize, builtin_corpus};
use bulkmi::mi::backend::{compute_mi, Backend};
use bulkmi::mi::entropy::{normalized_mi, Normalization};
use bulkmi::mi::topk::top_k_pairs;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let docs = builtin_corpus();
    let ds = binarize(&docs, 2, 200);
    println!(
        "corpus: {} docs, vocabulary {} words, sparsity {:.3}",
        ds.n_rows(),
        ds.n_cols(),
        ds.sparsity()
    );

    let mi = compute_mi(&ds, Backend::BulkOpt)?;
    let nmi = normalized_mi(&ds, &mi, Normalization::Mean);

    println!("\ntop word associations (symmetric uncertainty):");
    let names = ds.names().unwrap();
    for p in top_k_pairs(&nmi, 12) {
        println!("  {:<14} <-> {:<14} {:.3}", names[p.i], names[p.j], p.mi);
    }

    // sanity: at least one association from each topic cluster shows up
    let top: Vec<(String, String)> = top_k_pairs(&nmi, 12)
        .iter()
        .map(|p| (names[p.i].clone(), names[p.j].clone()))
        .collect();
    let has_pair = |a: &str, b: &str| {
        top.iter().any(|(x, y)| (x == a && y == b) || (x == b && y == a))
    };
    // these co-occur in every document of their topic
    assert!(
        has_pair("game", "team") || has_pair("championship", "team") || has_pair("game", "the"),
        "sports topic missing from top associations: {top:?}"
    );
    println!("\ntext associations OK");
    Ok(())
}
