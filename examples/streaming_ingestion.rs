//! Streaming scenario: rows arrive in chunks (a sequencing run, a log
//! stream) and MI must be available at any point without keeping the
//! rows. Uses the coordinator's [`StreamingAccumulator`] — the
//! optimized algorithm's sufficient statistics (G11, colsums, n) are
//! row-additive, so peak memory is one chunk + the m x m accumulator.
//!
//! ```sh
//! cargo run --release --example streaming_ingestion
//! ```

use bulkmi::coordinator::streaming::{ChunkGram, StreamingAccumulator};
use bulkmi::data::synth::SynthSpec;
use bulkmi::mi::backend::{compute_mi, Backend};
use bulkmi::mi::significance::{miller_madow, top_pairs_significance};
use bulkmi::util::timer::{fmt_secs, time_it};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // "the full run", which the streaming side never sees at once
    let full = SynthSpec::new(50_000, 300)
        .sparsity(0.92)
        .seed(5)
        .plant(10, 42, 0.03)
        .generate();
    let m = full.n_cols();
    println!("stream: {} total rows x {m} vars, arriving in 1,000-row chunks", full.n_rows());

    let mut acc = StreamingAccumulator::new(m, ChunkGram::Bitpack)?;
    let ((), secs) = time_it(|| {
        for start in (0..full.n_rows()).step_by(1000) {
            let len = 1000.min(full.n_rows() - start);
            let chunk = full.row_chunk(start, len).expect("chunk in range");
            acc.push_chunk(&chunk).expect("same width");
            if acc.n_chunks() % 20 == 0 {
                let snap = acc.snapshot().expect("rows ingested");
                println!(
                    "  after {:>6} rows: MI(10,42) = {:.4} bits",
                    acc.n_rows(),
                    snap.get(10, 42)
                );
            }
        }
    });
    println!("ingested {} chunks in {}", acc.n_chunks(), fmt_secs(secs));

    let streamed = acc.finalize()?;
    let monolithic = compute_mi(&full, Backend::BulkBitpack)?;
    assert_eq!(
        streamed.max_abs_diff(&monolithic),
        0.0,
        "streaming must be bit-identical to monolithic"
    );
    println!("streamed result bit-identical to monolithic ✓");

    // downstream: bias-correct and test significance of the top pairs
    let corrected = miller_madow(&full, &streamed);
    println!("\ntop pairs with permutation p-values (200 shuffles):");
    for (i, j, mi, p) in top_pairs_significance(&full, &corrected, 3, 200, 7) {
        println!("  ({i:>3}, {j:>3})  MI = {mi:.4}  p = {p:.4}");
    }
    let top = bulkmi::mi::topk::top_k_pairs(&corrected, 1);
    assert_eq!((top[0].i, top[0].j), (10, 42));
    println!("\nstreaming ingestion OK");
    Ok(())
}
