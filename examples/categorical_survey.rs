//! Non-binary extension (the paper's stated future work): mutual
//! information over *categorical* variables via one-hot expansion — one
//! binary Gram yields every pairwise contingency table at once.
//!
//! Scenario: a synthetic survey with demographic variables where some
//! answers depend on others; the bulk categorical MI recovers the
//! dependency structure.
//!
//! ```sh
//! cargo run --release --example categorical_survey
//! ```

// The numeric checks deliberately index by (row, col) to mirror the
// paper's pseudocode (same rationale as the crate-level allow in lib.rs).
#![allow(clippy::needless_range_loop)]

use bulkmi::mi::categorical::{
    categorical_entropies, mi_categorical, mi_pair_categorical, CategoricalDataset,
};
use bulkmi::util::rng::Rng;
use bulkmi::util::timer::{fmt_secs, time_it};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n = 20_000;
    let mut rng = Rng::new(17);
    // variables: region(5), age_band(4), product(6 <- depends on region),
    // channel(3 <- depends on age_band), satisfaction(5, independent)
    let mut data: Vec<u16> = Vec::with_capacity(n * 5);
    for _ in 0..n {
        let region = rng.gen_range(5) as u16;
        let age = rng.gen_range(4) as u16;
        let product = if rng.bernoulli(0.7) { region } else { rng.gen_range(6) as u16 };
        let channel = if rng.bernoulli(0.6) { age % 3 } else { rng.gen_range(3) as u16 };
        let satisfaction = rng.gen_range(5) as u16;
        data.extend_from_slice(&[region, age, product, channel, satisfaction]);
    }
    let ds = CategoricalDataset::new(n, 5, data)?;
    let names = ["region", "age_band", "product", "channel", "satisfaction"];
    println!(
        "survey: {} respondents x {} variables, cardinalities {:?} ({} one-hot cols)",
        n,
        ds.n_vars(),
        ds.cardinality(),
        ds.onehot_cols()
    );

    let (mi, secs) = time_it(|| mi_categorical(&ds));
    let mi = mi?;
    println!("bulk categorical MI in {} (one binary Gram)\n", fmt_secs(secs));

    let h = categorical_entropies(&ds);
    println!("{:<14} {}", "", names.join("  "));
    for i in 0..5 {
        print!("{:<14}", names[i]);
        for j in 0..5 {
            print!("{:>9.4} ", mi.get(i, j));
        }
        println!("   H = {:.3}", h[i]);
    }

    // the planted dependencies dominate
    assert!(mi.get(0, 2) > 10.0 * mi.get(0, 4), "region->product signal");
    assert!(mi.get(1, 3) > 10.0 * mi.get(1, 4), "age->channel signal");
    // bulk equals the explicit contingency oracle
    for x in 0..5 {
        for y in 0..5 {
            assert!((mi.get(x, y) - mi_pair_categorical(&ds, x, y)).abs() < 1e-10);
        }
    }
    println!("\nplanted dependencies recovered: region->product MI = {:.4}, age->channel MI = {:.4}", mi.get(0, 2), mi.get(1, 3));
    println!("categorical survey OK");
    Ok(())
}
