//! END-TO-END DRIVER: exercises every layer of the stack on a real
//! small workload and reports the paper's headline metric (bulk-vs-
//! pairwise speedup). Recorded in EXPERIMENTS.md.
//!
//! Pipeline stages:
//!   1. data      — synthetic genomics panel (the paper's motivating
//!                  domain), written to and re-read from .bmat;
//!   2. backends  — all native backends + the XLA/PJRT artifact path
//!                  (L1 Pallas / L2 JAX lowered, L3 executes) computed
//!                  on the same dataset, cross-validated cell by cell;
//!   3. coordinator — the same computation through the blockwise job
//!                  service (memory-budgeted plan, worker pool),
//!                  verified bit-identical to the monolithic run;
//!   4. analysis  — LD-pair recovery as the application-level check;
//!   5. report    — Table-1-style timing rows + the headline speedup.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use bulkmi::coordinator::planner::{block_for_budget, plan_blocks};
use bulkmi::coordinator::progress::Progress;
use bulkmi::coordinator::service::{JobService, JobSpec, JobStatus};
use bulkmi::coordinator::{run_plan_dense, run_plan_dense_serial, NativeProvider, XlaProvider};
use bulkmi::coordinator::executor::NativeKind;
use bulkmi::data::genomics::GenomicsSpec;
use bulkmi::data::io;
use bulkmi::mi::backend::{compute_mi_with, Backend};
use bulkmi::mi::measure::CombineKind;
use bulkmi::mi::topk::top_k_pairs;
use bulkmi::mi::xla::XlaMi;
use bulkmi::runtime::{ArtifactRegistry, Impl, XlaRuntime};
use bulkmi::util::timer::{fmt_secs, time_it};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    println!("=== bulkmi end-to-end pipeline ===\n");

    // ---- 1. data -------------------------------------------------------
    let spec = GenomicsSpec {
        n_samples: 20_000,
        n_markers: 500,
        n_causal: 8,
        ld_per_causal: 3,
        seed: 99,
        ..Default::default()
    };
    let panel = spec.generate();
    let path = std::env::temp_dir().join("bulkmi-e2e-panel.bmat");
    io::write_bmat(&panel.dataset, &path)?;
    let ds = io::read_bmat(&path)?;
    println!(
        "[data] {} samples x {} markers, sparsity {:.3}, {} on disk",
        ds.n_rows(),
        ds.n_cols(),
        ds.sparsity(),
        std::fs::metadata(&path)?.len()
    );

    // ---- 2. all backends on the same dataset ---------------------------
    println!("\n[backends] (paper Table-1 style)");
    println!("{:<22} {:>12} {:>14}", "implementation", "time", "max diff");
    let (reference, pair_secs) = time_it(|| compute_mi_with(&ds, Backend::Pairwise, 1));
    let reference = reference?;
    println!("{:<22} {:>12} {:>14}", "SKL Pairwise (ours)", fmt_secs(pair_secs), "reference");

    let mut bulk_best = f64::INFINITY;
    let mut bitpack_mi = None;
    for backend in [
        Backend::BulkBasic,
        Backend::BulkOpt,
        Backend::BulkSparse,
        Backend::BulkBitpack,
    ] {
        let (mi, secs) = time_it(|| compute_mi_with(&ds, backend, 1));
        let mi = mi?;
        let diff = mi.max_abs_diff(&reference);
        assert!(diff < 1e-10, "{backend}: diff {diff}");
        bulk_best = bulk_best.min(secs);
        println!("{:<22} {:>12} {:>14.2e}", backend.paper_label(), fmt_secs(secs), diff);
        if backend == Backend::BulkBitpack {
            bitpack_mi = Some(mi);
        }
    }
    let bitpack_mi = bitpack_mi.expect("bitpack ran");

    // XLA path (L1/L2 artifacts through PJRT), if artifacts are built
    match ArtifactRegistry::load_default() {
        Ok(reg) => {
            let xla = XlaMi::new(XlaRuntime::new(reg)?, Impl::Xla);
            let (mi, secs) = time_it(|| xla.compute(&ds));
            let mi = mi?;
            let diff = mi.max_abs_diff(&reference);
            assert!(diff < 1e-3, "xla diff {diff}");
            bulk_best = bulk_best.min(secs);
            println!("{:<22} {:>12} {:>14.2e}", "Opt-T (XLA/PJRT)", fmt_secs(secs), diff);
        }
        Err(e) => println!("{:<22} skipped ({e})", "Opt-T (XLA/PJRT)"),
    }

    let speedup = pair_secs / bulk_best;
    println!("\n[headline] best bulk vs pairwise speedup: {speedup:.0}x");

    // ---- 3. coordinator: blockwise + service ----------------------------
    let budget = 64 << 20; // 64 MiB working set per task
    let block = block_for_budget(ds.n_rows(), ds.n_cols(), budget);
    let plan = plan_blocks(ds.n_cols(), block)?;
    println!(
        "\n[coordinator] memory budget {} MiB -> block {} cols, {} tasks",
        budget >> 20,
        block,
        plan.tasks.len()
    );
    let provider = NativeProvider::new(&ds, NativeKind::Bitpack);
    let progress = Progress::new(plan.tasks.len());
    let (blockwise, secs) =
        time_it(|| run_plan_dense(&ds, &plan, &provider, 1, &progress, CombineKind::Mi));
    let blockwise = blockwise?;
    assert_eq!(
        blockwise.max_abs_diff(&bitpack_mi),
        0.0,
        "blockwise must be bit-identical to the monolithic bitpack run"
    );
    println!("  blockwise run: {} (bit-identical to monolithic)", fmt_secs(secs));

    // XLA provider through the coordinator (column-blocked xgram path)
    if let Ok(reg) = ArtifactRegistry::load_default() {
        let xla = XlaMi::new(XlaRuntime::new(reg)?, Impl::Xla);
        let xprov = XlaProvider::new(xla, Impl::Xla, &ds);
        let xplan = plan_blocks(ds.n_cols(), 256)?;
        let xprog = Progress::new(xplan.tasks.len());
        let (xmi, xsecs) =
            time_it(|| run_plan_dense_serial(&ds, &xplan, &xprov, &xprog, CombineKind::Mi));
        let xmi = xmi?;
        let diff = xmi.max_abs_diff(&reference);
        assert!(diff < 1e-3, "xla blockwise diff {diff}");
        println!("  xla blockwise (256-col xgram blocks): {} (diff {diff:.1e})", fmt_secs(xsecs));
    }

    // the job service surface
    let svc = JobService::new(2, 4);
    let h = svc.submit(
        ds.clone(),
        JobSpec::builder().backend(Backend::BulkBitpack).block_cols(block).build()?,
    )?;
    let status = svc.wait(h)?;
    let JobStatus::Done(out) = status else {
        panic!("service job failed: {status:?}");
    };
    let service_mi = out.into_dense().expect("dense-sink job returns a matrix");
    assert_eq!(service_mi.max_abs_diff(&bitpack_mi), 0.0);
    println!("  job service round-trip OK\n{}", svc.metrics().report());

    // ---- 4. application-level check ------------------------------------
    let k = panel.ld_pairs.len();
    let top = top_k_pairs(&reference, k);
    let truth: std::collections::HashSet<(usize, usize)> =
        panel.ld_pairs.iter().copied().collect();
    let sibling = |i: usize, j: usize| {
        panel.ld_pairs.iter().any(|&(c, l)| l == i || c == i)
            && panel.ld_pairs.iter().any(|&(c, l)| l == j || c == j)
    };
    let hits = top.iter().filter(|p| truth.contains(&(p.i, p.j)) || sibling(p.i, p.j)).count();
    println!("[analysis] LD recovery: {hits}/{k} of top-{k} pairs hit linkage structure");
    assert!(hits as f64 / k as f64 >= 0.8);

    println!("\n=== e2e pipeline OK (speedup {speedup:.0}x) ===");
    Ok(())
}
