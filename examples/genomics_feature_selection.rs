//! Genomics scenario (paper §1: "selecting genetic markers associated
//! with diseases"): generate a synthetic SNP presence/absence panel with
//! known causal markers and linkage structure, then
//!
//! 1. recover the linkage-disequilibrium (LD) pairs from the MI matrix,
//! 2. rank markers by MI with the disease label and select a
//!    non-redundant panel with mRMR.
//!
//! ```sh
//! cargo run --release --example genomics_feature_selection
//! ```

use bulkmi::data::genomics::GenomicsSpec;
use bulkmi::mi::backend::{compute_mi, Backend};
use bulkmi::mi::pairwise::mi_between;
use bulkmi::mi::topk::{mrmr_select, top_k_pairs};
use bulkmi::util::timer::{fmt_secs, time_it};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let spec = GenomicsSpec {
        n_samples: 4000,
        n_markers: 400,
        n_causal: 6,
        ld_per_causal: 3,
        seed: 13,
        ..Default::default()
    };
    let panel = spec.generate();
    let ds = &panel.dataset;
    println!(
        "panel: {} samples x {} markers ({} causal, {} LD pairs), sparsity {:.3}",
        ds.n_rows(),
        ds.n_cols(),
        panel.causal.len(),
        panel.ld_pairs.len(),
        ds.sparsity()
    );

    // -- marker-marker structure: bulk MI + top pairs -------------------
    let (mi, secs) = time_it(|| compute_mi(ds, Backend::BulkBitpack));
    let mi = mi?;
    println!("bulk MI over {} marker pairs in {}", 400 * 399 / 2, fmt_secs(secs));

    let k = panel.ld_pairs.len();
    let top = top_k_pairs(&mi, k);
    let truth: std::collections::HashSet<(usize, usize)> =
        panel.ld_pairs.iter().copied().collect();
    // count recovered LD pairs among top-k, also allowing LD-LD siblings
    // (markers linked to the same causal variant are mutually dependent)
    let sibling = |i: usize, j: usize| {
        panel.ld_pairs.iter().any(|&(c, l)| l == i || c == i)
            && panel.ld_pairs.iter().any(|&(c, l)| l == j || c == j)
    };
    let hits = top.iter().filter(|p| truth.contains(&(p.i, p.j)) || sibling(p.i, p.j)).count();
    let precision = hits as f64 / k as f64;
    println!("top-{k} pairs: {hits} hit linkage structure (precision {precision:.2})");
    println!("  strongest: ({}, {}) MI = {:.4} bits", top[0].i, top[0].j, top[0].mi);

    // -- marker-disease relevance + mRMR panel --------------------------
    let target_mi: Vec<f64> = (0..ds.n_cols())
        .map(|c| {
            let col: Vec<u8> = (0..ds.n_rows()).map(|r| ds.get(r, c)).collect();
            mi_between(&col, &panel.disease)
        })
        .collect();
    let selected = mrmr_select(&mi, &target_mi, 6);
    println!("\nmRMR-selected panel (6 markers): {selected:?}");
    let causal_blocks: Vec<usize> = selected
        .iter()
        .map(|&s| s / (1 + spec.ld_per_causal)) // block id of the marker
        .filter(|&b| b < spec.n_causal)
        .collect();
    let distinct: std::collections::HashSet<usize> = causal_blocks.iter().copied().collect();
    println!(
        "  markers covering {} of {} causal blocks (redundancy avoided: {})",
        distinct.len(),
        spec.n_causal,
        selected.len() - causal_blocks.len() + distinct.len() == selected.len()
    );

    assert!(precision >= 0.8, "LD recovery precision {precision} too low");
    assert!(distinct.len() >= 4, "mRMR should cover most causal blocks");
    println!("\ngenomics feature selection OK");
    Ok(())
}
